"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  The backbone is a stack of Mamba2 blocks with a
*shared* full transformer block (attention + MLP, parameters shared across
invocations) interleaved every 6 layers, following the Zamba2 design.

Hybrid/SSM family -> runs long_500k (SSM state is O(1); the shared attention
invocations keep a KV cache, sharded over the mesh).
"""

from repro.configs.base import BlockKind, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    layer_pattern=(BlockKind.MAMBA2,),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    shared_attn_every=6,
    rope_theta=10000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
