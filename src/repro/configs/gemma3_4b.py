"""gemma3-4b — dense decoder with 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144.  5 sliding-window layers (window 1024) per 1 global
layer.  Because 29/34 layers are local (sub-quadratic, O(1)-bounded KV) we DO
run long_500k for this arch: global layers keep a full (sharded) KV while
local layers keep a ring-buffer window cache.
"""

from repro.configs.base import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    layer_pattern=(BlockKind.LOCAL_ATTN_MLP,) * 5 + (BlockKind.ATTN_MLP,),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    attn_logit_softcap=0.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
