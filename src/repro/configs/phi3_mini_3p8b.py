"""phi3-mini-3.8b — dense decoder, RoPE + SwiGLU.

[arXiv:2404.14219; unverified]  32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064.  Pure full attention -> long_500k skipped.
"""

from repro.configs.base import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    layer_pattern=(BlockKind.ATTN_MLP,),
    rope_theta=10000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
