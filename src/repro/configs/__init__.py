"""Architecture registry: ``get_config(arch_id)`` resolves ``--arch`` ids."""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ALL_SHAPES, SHAPES_BY_NAME, BlockKind,
                                MLAConfig, ModelConfig, MoEConfig,
                                ParallelConfig, ResidualMode, RWKVConfig,
                                ShapeConfig, SSMConfig, TrainConfig,
                                DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K)


def _build_registry() -> Dict[str, ModelConfig]:
    from repro.configs import (dbrx_132b, deepseek_v2_lite_16b, gemma3_4b,
                               ladder_llama, llava_next_mistral_7b,
                               phi3_mini_3p8b, phi4_mini_3p8b, rwkv6_7b,
                               stablelm_3b, whisper_small, zamba2_2p7b)

    cfgs: List[ModelConfig] = [
        # --- the 10 assigned architectures ---
        zamba2_2p7b.CONFIG,
        phi4_mini_3p8b.CONFIG,
        stablelm_3b.CONFIG,
        gemma3_4b.CONFIG,
        phi3_mini_3p8b.CONFIG,
        whisper_small.CONFIG,
        deepseek_v2_lite_16b.CONFIG,
        dbrx_132b.CONFIG,
        rwkv6_7b.CONFIG,
        llava_next_mistral_7b.CONFIG,
        # --- the paper's own benchmark family ---
        ladder_llama.LADDER_1B,
        ladder_llama.LADDER_3B,
        ladder_llama.LLAMA_8B,
        ladder_llama.LLAMA_34B,
        ladder_llama.LLAMA_70B,
        ladder_llama.BLOOM_176B,
        ladder_llama.LLAMA_405B,
    ]
    return {c.name: c for c in cfgs}


REGISTRY: Dict[str, ModelConfig] = _build_registry()

# The 10 assigned architecture ids (40 dry-run cells).
ASSIGNED_ARCHS = (
    "zamba2-2.7b", "phi4-mini-3.8b", "stablelm-3b", "gemma3-4b",
    "phi3-mini-3.8b", "whisper-small", "deepseek-v2-lite-16b", "dbrx-132b",
    "rwkv6-7b", "llava-next-mistral-7b",
)


def get_config(arch: str, residual: str | None = None, **overrides) -> ModelConfig:
    """Resolve an ``--arch`` id, optionally forcing a residual mode."""
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    cfg = REGISTRY[arch]
    if residual is not None:
        cfg = cfg.replace(residual_mode=ResidualMode(residual))
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def dryrun_cells(archs=ASSIGNED_ARCHS):
    """Yield every (arch, shape) dry-run cell.

    Unsupported shapes (e.g. long_500k on pure full-attention archs) are
    yielded with supported=False so callers can record the documented skip.
    """
    for arch in archs:
        cfg = REGISTRY[arch]
        for shape in ALL_SHAPES:
            yield cfg, shape, shape.name in cfg.supported_shapes


__all__ = [
    "ALL_SHAPES", "ASSIGNED_ARCHS", "BlockKind", "DECODE_32K", "LONG_500K",
    "MLAConfig", "ModelConfig", "MoEConfig", "ParallelConfig", "PREFILL_32K",
    "REGISTRY", "ResidualMode", "RWKVConfig", "SHAPES_BY_NAME", "SSMConfig",
    "ShapeConfig", "TRAIN_4K", "TrainConfig", "dryrun_cells", "get_config",
]
