"""whisper-small — encoder-decoder audio model (backbone only).

[arXiv:2212.04356; unverified]  12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  The conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, enc_seq, d_model).  The decoder
carries self-attention + cross-attention + MLP per layer.  Full attention ->
long_500k skipped; decode shapes decode against the encoder context.
"""

from repro.configs.base import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,              # decoder layers
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    layer_pattern=(BlockKind.CROSS_ATTN,),
    frontend="audio",
    encoder_seq_ratio=2,      # 2 audio frames per decoded token (stub ratio)
    gated_mlp=False,          # whisper uses GELU MLP
    rope_theta=10000.0,       # backbone stub uses RoPE in place of learned pos
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
