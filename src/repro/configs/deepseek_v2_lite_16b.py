"""deepseek-v2-lite-16b — MoE with multi-head latent attention (MLA).

[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff=1408(per expert)
vocab=102400, MLA kv_lora=512, MoE 64 routed experts top-6 + 2 shared.
Layer 0 uses a dense FFN (width 10944) per the HF reference.  (The assignment
line lists both "64e top-6" and "160 routed"; we follow the verified
V2-Lite config: 64 routed + 2 shared, top-6 — noted in DESIGN.md.)

MLA compresses the KV cache to kv_lora_rank + qk_rope_head_dim per token,
but attention is still full -> long_500k skipped.
"""

from repro.configs.base import BlockKind, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                # per-expert hidden
    dense_d_ff=10944,         # layer-0 dense FFN
    vocab_size=102400,
    layer_pattern=(BlockKind.MLA_MOE,),
    layer_overrides=((0, BlockKind.MLA_MLP),),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_rope_head_dim=64,
                  qk_nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  capacity_factor=1.25, moe_d_ff=1408),
    rope_theta=10000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
