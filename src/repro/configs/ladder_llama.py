"""The paper's own model family: Llama-3-style transformers at the sizes
benchmarked in Ladder-Residual (Table 1): 1B, 3B, 8B, 34B, 70B, 176B, 405B.

``residual_mode`` selects Standard / Ladder / Parallel / Desync-nx / NoComm —
the same backbone is used for all five variants, mirroring the paper's
benchmark setup (§3.3.1).  The 1B/3B configs mirror the pretraining-from-
scratch experiments (§4.1, StarCoder tokenizer vocab 49152, 2048 ctx); the
8B/70B/405B configs mirror Llama-3.1.
"""

from repro.configs.base import BlockKind, ModelConfig

_COMMON = dict(
    family="dense",
    layer_pattern=(BlockKind.ATTN_MLP,),
    rope_theta=500000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)

LADDER_1B = ModelConfig(
    name="ladder-1b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=5504, vocab_size=49152, **_COMMON)

LADDER_3B = ModelConfig(
    name="ladder-3b", n_layers=26, d_model=3072, n_heads=24, n_kv_heads=24,
    d_ff=8192, vocab_size=49152, **_COMMON)

LLAMA_8B = ModelConfig(
    name="llama3-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, **_COMMON)

LLAMA_34B = ModelConfig(
    name="llama-34b", n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=32000, **_COMMON)

LLAMA_70B = ModelConfig(
    name="llama3-70b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, **_COMMON)

BLOOM_176B = ModelConfig(
    name="bloom-176b", n_layers=70, d_model=14336, n_heads=112, n_kv_heads=112,
    d_ff=4 * 14336, vocab_size=250880, gated_mlp=False, family="dense",
    layer_pattern=(BlockKind.ATTN_MLP,), rope_theta=10000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"))

LLAMA_405B = ModelConfig(
    name="llama3-405b", n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256, **_COMMON)

CONFIG = LLAMA_70B  # canonical paper benchmark model
