"""stablelm-3b — dense decoder.

[hf:stabilityai/stablelm-2-1_6b; unverified]  32L d_model=2560 32H
(GQA kv=32) d_ff=6912 vocab=50304.  Pure full attention -> long_500k skipped.
"""

from repro.configs.base import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    layer_pattern=(BlockKind.ATTN_MLP,),
    rope_theta=10000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
