"""dbrx-132b — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]  40L d_model=6144 48H (GQA kv=8)
d_ff=10752(per expert) vocab=100352.  Full attention -> long_500k skipped.
"""

from repro.configs.base import BlockKind, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    layer_pattern=(BlockKind.ATTN_MOE,),
    moe=MoEConfig(num_experts=16, num_shared_experts=0, top_k=4,
                  capacity_factor=1.25, moe_d_ff=10752),
    rope_theta=500000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
