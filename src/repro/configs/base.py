"""Configuration system for the Ladder-Residual reproduction framework.

Every model in the zoo is described by a :class:`ModelConfig`.  The config is a
plain frozen dataclass so that it can be hashed and used as a static argument
to ``jax.jit``.  Architectural families are distinguished by the per-layer
``layer_pattern``: a tuple of block descriptors, each of which names the
sub-blocks ("mixer" + optional "ffn") that make up one layer.  The Ladder
Residual rewiring (the paper's contribution) is orthogonal to the family and
selected via ``residual_mode``.
"""

from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass
from typing import Optional, Tuple


class ResidualMode(str, enum.Enum):
    """Residual-stream wiring of the transformer stack.

    STANDARD   x_{i+1} = AllReduce(h_{i+1}(x_i)) + x_i          (Eq. 1)
    LADDER     x_{i+1} = AllReduce(h_{i+1}(x_{i-1})) + x_i      (Eq. 2, the paper)
    PARALLEL   PaLM-style fused attention+MLP, one AllReduce per layer
    DESYNC2    drop every other AllReduce (Desync Residual-2x, §5)
    DESYNC4    keep 1 of every 4 AllReduces (Desync Residual-4x, §5)
    NO_COMM    drop all AllReduces — the paper's "Upper Bound" (incorrect math,
               used only for benchmarking the communication-free limit).
    """

    STANDARD = "standard"
    LADDER = "ladder"
    PARALLEL = "parallel"
    DESYNC2 = "desync2"
    DESYNC4 = "desync4"
    NO_COMM = "no_comm"


class BlockKind(str, enum.Enum):
    """Kind of one layer (a layer = mixer sub-block + optional ffn sub-block)."""

    ATTN_MLP = "attn_mlp"            # classic transformer block
    ATTN_MOE = "attn_moe"            # attention + mixture-of-experts FFN
    MLA_MOE = "mla_moe"              # multi-head latent attention + MoE FFN
    MLA_MLP = "mla_mlp"              # MLA + dense FFN (deepseek first layer)
    LOCAL_ATTN_MLP = "local_attn_mlp"  # sliding-window attention + MLP
    MAMBA2 = "mamba2"                # single-module Mamba2 block (no FFN)
    SHARED_ATTN_MLP = "shared_attn_mlp"  # zamba2 shared transformer block
    RWKV6 = "rwkv6"                  # RWKV6 time-mix + channel-mix
    CROSS_ATTN = "cross_attn"        # enc-dec cross attention sub-block(s)


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: an input shape paired with the step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes.  ``decode_*``/``long_*`` lower ``serve_step``
# (one new token against a KV cache of ``seq_len``), not ``train_step``.
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # always-on shared experts (deepseek style)
    top_k: int = 1
    capacity_factor: float = 1.25   # train-time token capacity per expert
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01   # load-balance loss weight
    moe_d_ff: int = 0               # per-expert hidden size (0 -> use d_ff)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 -> no query compression
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 block configuration."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256           # chunked SSD scan length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64            # low-rank data-dependent decay projection
    chunk_size: int = 128


@dataclass(frozen=True)
class ModelConfig:
    """Complete description of one architecture."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0               # 0 -> d_model // n_heads
    # Per-layer pattern; cycled to cover n_layers.  E.g. gemma3 uses
    # (LOCAL,)*5 + (GLOBAL,) repeated.  A scan runs over groups of
    # len(layer_pattern) layers with stacked parameters.
    layer_pattern: Tuple[BlockKind, ...] = (BlockKind.ATTN_MLP,)
    # Layer indices (absolute) overriding the pattern, e.g. deepseek layer 0.
    layer_overrides: Tuple[Tuple[int, BlockKind], ...] = ()

    # positional encoding / attention details
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 -> full attention for LOCAL blocks
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    gated_mlp: bool = True          # SwiGLU vs GELU-MLP
    attn_logit_softcap: float = 0.0
    dense_d_ff: int = 0             # FFN width for dense-override layers

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # zamba2-style shared transformer block applied every `shared_attn_every`
    # layers (parameters are shared across invocations).
    shared_attn_every: int = 0

    # encoder-decoder (whisper): if >0 the model has an encoder stack of this
    # many layers and `n_layers` decoder layers with cross attention.
    encoder_layers: int = 0
    encoder_seq_ratio: int = 1      # encoder frames per decoder token (stub)

    # modality frontend stub: "none" | "audio" | "vision".  input_specs()
    # provides precomputed frame/patch embeddings for non-"none" frontends.
    frontend: str = "none"
    num_patches: int = 0            # vlm: patch embeddings prepended per image

    # ---- paper knob ----
    residual_mode: ResidualMode = ResidualMode.STANDARD
    # apply ladder only to layers >= this index (hybrid adaptation, §4.2)
    ladder_start_layer: int = 0

    # ---- runtime knobs ----
    dtype: str = "bfloat16"
    remat: str = "block"            # none | block | dots
    use_pallas: bool = False        # use Pallas kernels for hot paths
    use_flash_decode: bool = False  # seq-sharded flash decoding over 'data'
    mla_flash_decode: bool = False  # MLA latent cache seq-sharded over MODEL
    fused_qkv: bool = True
    max_position_embeddings: int = 1 << 20

    # which assigned shapes this arch runs; e.g. pure full-attention archs
    # skip long_500k (noted in DESIGN.md §Arch-applicability).
    supported_shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def block_kind(self, layer_idx: int) -> BlockKind:
        for idx, kind in self.layer_overrides:
            if idx == layer_idx:
                return kind
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def layer_kinds(self) -> Tuple[BlockKind, ...]:
        return tuple(self.block_kind(i) for i in range(self.n_layers))

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, n_layers: int = 2, d_model: int = 64, n_heads: int = 4,
                n_kv_heads: int = 0, d_ff: int = 128, vocab_size: int = 256,
                **kw) -> "ModelConfig":
        """A tiny config of the same family for CPU smoke tests."""
        n_kv = n_kv_heads or max(1, n_heads * self.n_kv_heads // max(self.n_heads, 1))
        upd = dict(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, d_ff=d_ff, vocab_size=vocab_size,
            head_dim=d_model // n_heads, dtype="float32", remat="none",
            dense_d_ff=min(self.dense_d_ff, 2 * d_ff) if self.dense_d_ff else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
        )
        if self.moe is not None:
            upd["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(2, self.moe.top_k),
                num_shared_experts=min(1, self.moe.num_shared_experts),
                moe_d_ff=d_ff // 2 if self.moe.moe_d_ff else 0)
        if self.mla is not None:
            upd["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                   qk_rope_head_dim=8, qk_nope_head_dim=8,
                                   v_head_dim=16)
        if self.ssm is not None:
            upd["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                             chunk_size=8)
        if self.rwkv is not None:
            upd["rwkv"] = dataclasses.replace(self.rwkv, head_dim=16,
                                              decay_lora=8, chunk_size=8)
        if self.shared_attn_every:
            upd["shared_attn_every"] = 2
        if self.encoder_layers:
            upd["encoder_layers"] = n_layers
        if self.num_patches:
            upd["num_patches"] = 4
        if self.layer_overrides:
            upd["layer_overrides"] = tuple((i, k) for i, k in self.layer_overrides
                                           if i < n_layers)
        upd.update(kw)
        return self.replace(**upd)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND roofline accounting)."""
        from repro.models.model import count_params_analytical
        return count_params_analytical(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytical
        return count_params_analytical(self, active_only=True)


@dataclass(frozen=True)
class ParallelConfig:
    """How a step is sharded over the mesh."""

    tp: int = 1                     # size of 'model' axis
    dp: int = 1                     # size of 'data' axis
    pp: int = 1                     # size of 'pod' axis used as pipeline
    pods: int = 1                   # size of 'pod' axis used as extra DP
    use_sp: bool = False            # Megatron-style sequence parallelism
    shard_seq_for_decode: bool = False  # long-context flash decoding over 'data'
    grad_compression: str = "none"  # none | int8 | topk
    fsdp: bool = False              # shard params/opt-state over 'data'
    microbatches: int = 1           # pipeline microbatches (pp>1)

    @property
    def world(self) -> int:
        return self.tp * self.dp * self.pp * self.pods


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    learning_rate: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    grad_accum: int = 1
    z_loss: float = 0.0
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    log_every: int = 10
