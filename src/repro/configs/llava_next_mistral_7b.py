"""llava-next-mistral-7b — VLM; Mistral-7B backbone with anyres tiling stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The vision tower + anyres tiling is a
STUB: ``input_specs()`` provides pre-projected patch embeddings
(batch, num_patches, d_model) that are prepended to the token embeddings.
Full attention -> long_500k skipped.
"""

from repro.configs.base import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    layer_pattern=(BlockKind.ATTN_MLP,),
    frontend="vision",
    num_patches=576,          # one 24x24 CLIP tile (anyres adds more tiles)
    rope_theta=1_000_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
