"""phi4-mini-3.8b — dense decoder, RoPE + SwiGLU + GQA.

[arXiv:2412.08905; hf]  32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064.  Pure full attention -> long_500k skipped (DESIGN.md
§Arch-applicability).
"""

from repro.configs.base import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    layer_pattern=(BlockKind.ATTN_MLP,),
    rope_theta=10000.0,
    tie_embeddings=True,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
