"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536.
Each layer = time-mix (WKV6 recurrence) + channel-mix.  State is O(1) per
layer -> runs long_500k.
"""

from repro.configs.base import BlockKind, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,               # wkv heads = d_model / head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=(BlockKind.RWKV6,),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk_size=128),
    gated_mlp=False,          # rwkv channel-mix is its own gating
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
