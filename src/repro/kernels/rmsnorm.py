"""Pallas TPU fused RMSNorm.

One pass: rows stream through VMEM in (block_rows, D) tiles; the mean-square
reduction, rsqrt and the (1 + w) scale fuse into a single kernel — vs three
HBM round-trips for the unfused lowering (read x for the reduction, read x
again for the normalise, write y).  D is the full feature width per tile so
no cross-tile reduction is needed (d_model <= 16k fits VMEM comfortably:
8 rows x 16k x 4 B = 0.5 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm(x, weight, *, eps: float = 1e-5, block_rows: int = 8,
            interpret: bool = False):
    """x: (..., D); weight: (D,) stored zero-centred (gemma convention)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    n = -(-rows // block_rows)
    pad = n * block_rows - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n * block_rows, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out[:rows].reshape(orig_shape)


def _dequant_kernel(x_ref, q_ref, s_ref, w_ref, o_ref, *, eps: float,
                    tp: int):
    # dequant-accumulate the per-source int8 images onto the base rows in
    # f32 SOURCE ORDER (the ring's fixed association — bit-identical to
    # PendingResidual.materialize), then the usual fused norm.  The tp loop
    # unrolls: tp is tiny (<= 8) and each image tile is int8, so the whole
    # working set stays in VMEM for one HBM pass.
    x = x_ref[...].astype(jnp.float32)                      # (br, D)
    for j in range(tp):
        x = x + q_ref[j].astype(jnp.float32) * s_ref[j][:, None]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm_dequant(x, images, scales, weight, *, eps: float = 1e-5,
                    block_rows: int = 8, interpret: bool = False):
    """Fused dequant + RMSNorm: ``rmsnorm(x + sum_j images[j] * scales[j])``
    in ONE pass over HBM.

    x: (..., D) base rows; images: (tp, ..., D) int8 per-source quantized
    partials with per-row ``scales`` (tp, ...) (repro.quant.quantize_kv
    layout — the deferred AllReduce wire of parallel/overlap.
    ring_block_images).  The unfused lowering reads the f32 sum back from
    HBM between the dequant-add and the norm; here the int8 images
    dequantize in VMEM and only the normed rows are written
    (DESIGN.md §Communication overlap, fused-norm decode path).
    """
    orig_shape = x.shape
    d = x.shape[-1]
    tp = images.shape[0]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    q2 = images.reshape(tp, rows, d)
    s2 = scales.astype(jnp.float32).reshape(tp, rows)
    block_rows = min(block_rows, rows)
    n = -(-rows // block_rows)
    pad = n * block_rows - rows
    if pad:
        # zero-scale padding rows dequantize to exactly zero
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        q2 = jnp.pad(q2, ((0, 0), (0, pad), (0, 0)))
        s2 = jnp.pad(s2, ((0, 0), (0, pad)))

    out = pl.pallas_call(
        functools.partial(_dequant_kernel, eps=eps, tp=tp),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((tp, block_rows, d), lambda i: (0, i, 0)),
            pl.BlockSpec((tp, block_rows), lambda i: (0, i)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n * block_rows, d), x.dtype),
        interpret=interpret,
    )(x2, q2, s2, weight)
    return out[:rows].reshape(orig_shape)
