"""Pallas TPU WKV6 recurrence (RWKV6 time-mix core).

Grid (B, H, nC): the per-head state S ∈ R^{hd x hd} persists in VMEM scratch
across a head's chunks; within a chunk the recurrence is evaluated
step-by-step with rank-1 updates (VPU-bound — the data-dependent per-channel
decay w_t makes the chunked matmul form numerically hazardous because it
needs exp(+cumsum) factors; production variants renormalise per chunk, we
keep the kernel exact and move throughput to the chunk level).

VMEM per cell at (L=64, hd=64): 4 input tiles + state ≈ 90 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                      # (hd,)

    def step(t, carry):
        s, y = carry
        rt = r_ref[0, 0, t].astype(jnp.float32)           # (hd,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                    # (hd, hd)
        yt = jnp.sum((s + u[:, None] * kv) * rt[:, None], axis=0)
        s = s * wt[:, None] + kv
        y = y.at[t].set(yt)
        return s, y

    y0 = jnp.zeros((chunk, s_ref.shape[1]), jnp.float32)
    s, y = jax.lax.fori_loop(0, chunk, step, (s_ref[...], y0))
    s_ref[...] = s
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        sout_ref[0, 0] = s_ref[...]


def rwkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w: (B,S,H,hd); u: (H,hd).  Zero initial state.

    Returns (y (B,S,H,hd), s_last (B,H,hd,hd) fp32).
    """
    bsz, s, h, hd = r.shape
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        # pad with w=1 (identity decay) so state stays untouched
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)

    args = [t.transpose(0, 2, 1, 3) for t in (r, k, v, w)]  # (B,H,S,hd)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, s_last = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, hd), lambda b, hh, c: (hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc * chunk, hd), r.dtype),
            jax.ShapeDtypeStruct((bsz, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(*args, u.astype(jnp.float32))
    return y.transpose(0, 2, 1, 3)[:, :s], s_last
