"""Pallas comm kernels for the overlapped ring AllReduce.

Two kernels back :mod:`repro.parallel.overlap`:

``dequant_accumulate``
    fused dequantize(int8 ``q``, per-256-block ``scale``) + **masked**
    accumulate onto an f32 accumulator — the reduce step of the compressed
    ring.  Ring payloads are padded to whole quant blocks and the pad tail
    of a reused wire buffer may hold *anything* (a stale chunk, 1e38, NaN);
    the in-kernel mask keeps it out of the sum.  Poisoned-tail isolation
    and the chunk-boundary off-by-ones are pinned in interpret mode by
    tests/test_collectives.py; on TPU the same kernel runs compiled.

``ring_all_reduce_remote``
    the chunk rotation itself as explicit double-buffered
    ``pltpu.make_async_remote_copy`` DMA (one neighbour push per step,
    send/recv slots alternating ``step % 2`` / ``(step + 1) % 2``), with
    every shard's contribution landed in a by-source VMEM buffer and
    summed in source order — the same determinism contract as the
    ppermute fallback (cross-shard bit-identity; psum bit-equality at
    tp=2).  Remote DMA has no cross-device interpret mode, so this path is
    TPU-only (``jax.default_backend() == "tpu"``); everything else runs
    the fallback, and the shared schedule helpers (``chunk_bounds``,
    source ordering) are what the fast tier pins.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant import BLOCK

_LANE = 128  # TPU lane width; remote-DMA payloads are padded to (rows, 128)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# masked dequantize-accumulate (compressed-ring reduce step)
# ---------------------------------------------------------------------------

def _dequant_acc_kernel(acc_ref, q_ref, s_ref, out_ref, *, valid: int):
    img = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]
    rows = jax.lax.broadcasted_iota(jnp.int32, img.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, img.shape, 1)
    # flat element index over the (blocks, BLOCK) quant grid; everything at
    # or beyond `valid` is pad/garbage and must contribute exactly zero.
    keep = rows * img.shape[1] + cols < valid
    out_ref[...] = acc_ref[...] + jnp.where(keep, img, 0.0)


def dequant_accumulate(acc, q, scale, valid: int, *, interpret=None):
    """``acc + dequantize(q, scale)[:valid]`` with the pad tail masked out.

    acc: (valid,) f32 running chunk sum.  q: (blocks, BLOCK) int8 wire
    payload; scale: (blocks,) f32.  ``valid`` is the chunk's true element
    count (static): ``blocks * BLOCK`` is the padded wire size, and the
    tail — q values *and* scales — may be garbage from buffer reuse.
    """
    blocks, blk = q.shape
    if blk != BLOCK:
        raise ValueError(f"expected quant block {BLOCK}, got {blk}")
    if not 0 < valid <= blocks * blk:
        raise ValueError(f"valid={valid} outside (0, {blocks * blk}]")
    if interpret is None:
        interpret = _default_interpret()
    accp = (
        jnp.zeros((blocks * blk,), jnp.float32)
        .at[:valid]
        .set(acc.astype(jnp.float32))
        .reshape(blocks, blk)
    )
    out = pl.pallas_call(
        functools.partial(_dequant_acc_kernel, valid=valid),
        out_shape=jax.ShapeDtypeStruct((blocks, blk), jnp.float32),
        interpret=interpret,
    )(accp, q, scale.astype(jnp.float32))
    return out.reshape(-1)[:valid]


# ---------------------------------------------------------------------------
# remote-DMA ring all-reduce (TPU only)
# ---------------------------------------------------------------------------

def _ring_chunk_kernel(x_ref, out_ref, comm_buf, gather_buf, send_sem,
                       recv_sem, *, tp: int, axis_name: str):
    my_id = jax.lax.axis_index(axis_name)
    right = jax.lax.rem(my_id + 1, tp)
    left = jax.lax.rem(my_id - 1 + tp, tp)

    # Neighbour barrier: nobody starts pushing into a buffer its neighbour
    # is still initialising (guide: Local Barrier Between Neighbors).
    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, inc=1, device_id=left)
    pltpu.semaphore_signal(barrier, inc=1, device_id=right)
    pltpu.semaphore_wait(barrier, 2)

    comm_buf[0] = x_ref[...]
    gather_buf[pl.dslice(my_id, 1)] = x_ref[...][None].astype(jnp.float32)

    for step in range(tp - 1):
        send_slot = step % 2
        recv_slot = (step + 1) % 2
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[send_slot],
            dst_ref=comm_buf.at[recv_slot],
            send_sem=send_sem.at[send_slot],
            recv_sem=recv_sem.at[recv_slot],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()
        # After `step + 1` hops we hold the chunk that originated at
        # (my_id - step - 1) % tp; land it in the by-source buffer so the
        # final sum can run in source order on every shard.
        src = jax.lax.rem(my_id - step - 1 + tp, tp)
        gather_buf[pl.dslice(src, 1)] = (
            comm_buf[recv_slot][None].astype(jnp.float32)
        )

    acc = gather_buf[0]
    for j in range(1, tp):  # fixed left-to-right association (see overlap.py)
        acc = acc + gather_buf[j]
    out_ref[...] = acc.astype(out_ref.dtype)


def _ring_chunk_remote(c, axis_name: str, tp: int):
    """One chunk of the remote-DMA ring: pad flat chunk to (rows, 128)."""
    n = c.shape[0]
    pad = (-n) % _LANE
    cp = jnp.pad(c, (0, pad)).reshape(-1, _LANE)
    rows = cp.shape[0]
    out = pl.pallas_call(
        functools.partial(_ring_chunk_kernel, tp=tp, axis_name=axis_name),
        out_shape=jax.ShapeDtypeStruct((rows, _LANE), c.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, rows, _LANE), c.dtype),       # comm_buf
            pltpu.VMEM((tp, rows, _LANE), jnp.float32),  # gather_buf
            pltpu.SemaphoreType.DMA((2,)),               # send_sem
            pltpu.SemaphoreType.DMA((2,)),               # recv_sem
        ],
        compiler_params=pltpu.CompilerParams(
            has_side_effects=True, collective_id=7
        ),
    )(cp)
    return out.reshape(-1)[:n]


def ring_all_reduce_remote(x, axis_name: str, *, chunks: int = 4):
    """Chunked AllReduce over ``axis_name`` via async remote-copy DMA.

    Same chunk schedule and source-ordered summation as
    ``overlap.ring_all_reduce`` — the two are interchangeable; dispatch
    (TPU backend only) happens in the caller.
    """
    from repro.parallel.overlap import _static_axis_size, chunk_bounds

    tp = _static_axis_size(axis_name)
    if tp == 1:
        return x
    flat = x.reshape(-1)
    pieces = [
        _ring_chunk_remote(flat[start:start + size], axis_name, tp)
        for start, size in chunk_bounds(flat.shape[0], chunks)
    ]
    return jnp.concatenate(pieces).reshape(x.shape)
