"""Pallas TPU Mamba2 SSD scan (chunked, per-(batch, head) grid).

Grid (B, H, nC) with the chunk dimension minor.  The SSM state
h ∈ R^{N x hd} persists in VMEM scratch across a head's chunks; each chunk
does the SSD block decomposition with MXU matmuls:

  intra:  y += ((C B^T) ⊙ decay-ratio ⊙ causal) (dt ⊙ x)
  inter:  y += (C ⊙ exp(cum)) h_prev
  state:  h  = exp(total) h_prev + (B ⊙ exp(total - cum))^T (dt ⊙ x)

VMEM per cell at (L=128, N=128, hd=64): x/B/C tiles + the (L, L) ratio
matrix + state ≈ 0.6 MB.  Exponent masking happens BEFORE exp (the upper
triangle would overflow — same guard as the jnp path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, dt_ref, alog_ref, y_ref, hout_ref, h_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = -jnp.exp(alog_ref[0].astype(jnp.float32))         # scalar
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)           # (L,)
    x = x_ref[0, 0].astype(jnp.float32) * dt[:, None]     # (L, hd)
    bm = b_ref[0, 0].astype(jnp.float32)                  # (L, N)
    cm = c_ref[0, 0].astype(jnp.float32)                  # (L, N)

    la = dt * a                                           # (L,) log decay
    cs = jnp.cumsum(la)                                   # (L,)
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmask = idx >= jdx
    diff = jnp.where(lmask, cs[:, None] - cs[None, :], -jnp.inf)
    ratio = jnp.exp(diff)                                 # (L, L)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * ratio
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk from carried state
    y = y + jax.lax.dot_general(cm * jnp.exp(cs)[:, None], h_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update
    tot = cs[-1]
    h_ref[...] = h_ref[...] * jnp.exp(tot) + jax.lax.dot_general(
        bm * jnp.exp(tot - cs)[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssm_scan(x, b_mat, c_mat, dt, a_log, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B,S,H,hd); b/c: (B,S,H,N); dt: (B,S,H) (softplus'd); a_log: (H,).

    Returns (y (B,S,H,hd), h_last (B,H,N,hd)).  Zero initial state (the
    decode path keeps state outside the kernel).
    """
    bsz, s, h, hd = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    # layout: (B, H, S, *) so the (b, h) grid dims are leading
    xt = x.transpose(0, 2, 1, 3)
    bt = b_mat.transpose(0, 2, 1, 3)
    ct = c_mat.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1)[..., None]

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, n, hd), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc * chunk, hd), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, hd), jnp.float32)],
        interpret=interpret,
    )(xt, bt, ct, dtt, a_log.astype(jnp.float32))
    y = y.transpose(0, 2, 1, 3)[:, :s]
    return y, h_last

