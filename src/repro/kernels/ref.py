"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each oracle is the most direct possible implementation — no blocking, no
numerics tricks beyond what the math requires — so kernel bugs cannot hide
behind shared structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, scale: float, window: int = 0,
                        softcap: float = 0.0):
    """q: (BH, S, hd); k, v: (BHkv, S, hd) with BH = BHkv * g.

    Plain causal softmax attention per head, fp32 accumulation.
    """
    bh, s, hd = q.shape
    g = bh // k.shape[0]
    kr = jnp.repeat(k, g, axis=0)
    vr = jnp.repeat(v, g, axis=0)
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale,
                        kr.astype(jnp.float32))
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def ssm_scan_ref(x, b_mat, c_mat, dt, a_log, h0=None):
    """Sequential Mamba2/SSD recurrence (the trusted slow path).

    x: (B,S,H,hd); b_mat/c_mat: (B,S,H,N); dt: (B,S,H) softplus'd;
    a_log: (H,).  Returns (y (B,S,H,hd), h_last (B,H,N,hd)).
    """
    bsz, s, h, hd = x.shape
    n = b_mat.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, hd), jnp.float32)

    def step(hprev, t):
        xt = (x[:, t] * dt[:, t][..., None]).astype(jnp.float32)  # (B,H,hd)
        decay = jnp.exp(dt[:, t] * a[None, :])[..., None, None]
        hnew = hprev * decay + jnp.einsum("bhn,bhd->bhnd",
                                          b_mat[:, t].astype(jnp.float32), xt)
        y = jnp.einsum("bhn,bhnd->bhd", c_mat[:, t].astype(jnp.float32), hnew)
        return hnew, y

    h_last, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return ys.swapaxes(0, 1).astype(x.dtype), h_last


def rwkv6_ref(r, k, v, w, u, s0):
    """Sequential WKV6 recurrence (fp32).

    r,k,v,w: (B,S,H,hd); u: (H,hd); s0: (B,H,hd,hd).
    y_t = (S_{t-1} + (u*k_t) v_t^T)^T r_t ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, t):
        kv = jnp.einsum("bhi,bhj->bhij", kf[:, t], vf[:, t])
        y = jnp.einsum("bhij,bhi->bhj", s + u[None, :, :, None] * kv,
                       rf[:, t])
        return s * wf[:, t][..., None] + kv, y

    s_last, ys = jax.lax.scan(step, s0, jnp.arange(r.shape[1]))
    return ys.swapaxes(0, 1).astype(r.dtype), s_last
