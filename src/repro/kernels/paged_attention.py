"""Pallas TPU paged attention: block-table-native flash decoding.

The serving engine's paged branch used to read the KV cache with a dense
gather (``paged_view``): every decode step materialised each row's entire
``max_blocks * block_size`` padded logical view before attending, so HBM
traffic scaled with pool width instead of actual sequence length.  This
kernel walks the block table *in-kernel* instead — the BlockSpec index_map
translates a logical block index into the physical pool block via a
scalar-prefetched ``block_tables`` argument, so only the row's own KV
blocks are ever streamed from HBM and bytes-read scales with
``ceil(kv_len / block_size)`` (benchmarks/kernel_bench.py pins the model).

Grid ``(B * Hkv, num_q_tiles, num_splits, blocks_per_split)``:

* axis 0 fuses (batch row, local kv head) — one online-softmax state per
  cell, GQA without materialised KV repetition: the tile's ``q_tile * G``
  query rows stay resident in VMEM while that kv head's tiles stream past
  (same trick as kernels/flash_attention.py, with the group dim folded
  into the q-tile rows instead of the grid).
* axis 1 tiles the query dimension (ragged prefill/append mode): each
  q-tile carries its own slice of the per-query positions, its own
  online-softmax state, and its own ragged early exit — a tile covering
  only early chunk positions stops streaming KV at ITS last position, not
  the chunk's.  ``q_tile = 0`` keeps the whole Q resident in one tile
  (exactly the pre-tiling behaviour — the decode default); the autotuner
  (kernels/autotune.py) picks the tile for prefill/verify shapes, trading
  VMEM residency against per-tile KV re-streaming.
* axis 2 is the split-K dimension: each split covers a contiguous range of
  logical blocks and emits PARTIAL softmax statistics ``(m, l, acc)``; the
  host-side combine (``_combine_splits``) merges them with exactly the
  ``(m, l)`` contract ``_cached_attention`` already uses for seq-sharded
  flash decoding, so a TP/DP stats combine composes unchanged on top.
* axis 3 walks the split's logical blocks (grid-minor: VMEM scratch carries
  the online-softmax state across iterations).  Tiles whose first position
  lies beyond the q-tile's last query position are skipped with ``pl.when``
  — the per-(row, q-tile) ragged early exit.

Queries are general ``Q >= 1`` with *per-query absolute positions*
(padding / inactive rows at -1), so plain decode (Q = 1), speculative K+1
verification and chunked prefill all run through the same kernel: the mask
``kv_pos <= q_pos`` is simultaneously the ragged length mask and the
causal mask among fresh tokens (their K/V is scatter-appended into the
pool by ``paged_update`` inside the same jitted step —
engine.build_paged_steps — so chunked prefill and K+1 verify never
materialise the ``paged_view`` gather).

int8 pools (DESIGN.md §KV memory tiers) add two scale-tile inputs walked
by the same logical -> physical index_map as the KV tiles: KV tiles load
as int8 and dequantize in VMEM against their per-(token, head) scales, so
HBM bytes-read drops a further ~4x (f32 pools) on top of the occupancy
win — benchmarks/kernel_bench.py carries the model,
scripts/check_bench.py gates it.

Validated in interpret mode against the ``paged_view`` gather oracle over
block_size x GQA group x ragged kv_len x Q x softcap
(tests/test_paged_kernel.py; int8 parity in tests/test_memory.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    bt_ref,
    qpos_ref,
    q_ref,
    k_ref,
    v_ref,
    *refs,
    scale: float,
    softcap: float,
    block_size: int,
    group: int,
    blocks_per_split: int,
    hkv: int,
    quant: bool,
):
    # int8 pools carry two extra inputs: per-token scale tiles walked by
    # the same logical -> physical index_map as the KV tiles (they are the
    # pool's block-major scale arrays reshaped (Hkv, nb, bs)); KV tiles
    # load as int8 and dequantize in VMEM, so HBM bytes-read drops ~4x vs
    # an f32 pool (benchmarks/kernel_bench.py pins the model)
    if quant:
        ks_ref, vs_ref, m_out, l_out, acc_out, m_ref, l_ref, acc_ref = refs
    else:
        m_out, l_out, acc_out, m_ref, l_ref, acc_ref = refs
    cell = pl.program_id(0)  # fused (row, kv head)
    t = pl.program_id(1)  # q-tile within the row's queries
    split = pl.program_id(2)
    j = pl.program_id(3)  # block within this split
    row = cell // hkv

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    logical = split * blocks_per_split + j
    qp = qpos_ref[row, t]  # (q_tile,) absolute query positions of THIS tile
    # ragged early exit: KV tiles past the q-tile's last query position
    # hold no readable KV (reads are masked to kv_pos <= q_pos); inactive
    # rows / pure-padding tiles (all positions -1) skip every KV tile and
    # emit l = 0
    in_range = logical * block_size <= jnp.max(qp)

    @pl.when(in_range)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (q_tile*G, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bs, hd)
        if quant:
            k = k * ks_ref[0, 0][:, None]
        s = jax.lax.dot_general(
            q,
            k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qg = q.shape[0]
        # query row i*G+g carries query i's position (the (q_tile, G)
        # layout below flattens row-major)
        qpg = jnp.repeat(qp, group, total_repeat_length=qg)
        kvpos = logical * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (qg, block_size), 1
        )
        s = jnp.where(kvpos <= qpg[:, None], s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        # query rows with no valid key yet keep l = 0 (m still NEG_INF
        # makes exp(s - m) collapse to exp(0) = 1, not 0)
        p = jnp.where(m_new[:, None] > NEG_INF / 2, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        if quant:
            v = v * vs_ref[0, 0][:, None]
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p,
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == blocks_per_split - 1)
    def _finalize():
        m_out[0, 0, 0] = m_ref[...]
        l_out[0, 0, 0] = l_ref[...]
        acc_out[0, 0, 0] = acc_ref[...]


def _combine_splits(ms, ls, accs):
    """Merge split-K partial stats over axis 1 — the flash-decoding
    ``(m, l)`` contract (cf. models/attention._cached_attention's
    seq-sharded combine, which psums the same quantities over 'data')."""
    m_glob = jnp.max(ms, axis=1)  # (BH, QG)
    corr = jnp.exp(ms - m_glob[:, None])
    num = jnp.sum(accs * corr[..., None], axis=1)  # (BH, QG, hd)
    den = jnp.sum(ls * corr, axis=1)
    return num / jnp.maximum(den, 1e-37)[..., None]


def prefill_kernel_blocks(kv_hi: int, chunk: int, q_tile: int,
                          block_size: int) -> int:
    """Analytical KV-block reads of the q-tiled kernel for ONE prefill
    chunk whose last query sits at absolute position ``kv_hi - 1``.

    Each q-tile streams logical blocks 0..ceil(tile_last_pos+1 / bs)-1
    (the per-tile ragged early exit) — so ``q_tile = 0`` (one tile) reads
    ceil(kv_hi / bs) blocks exactly once, while smaller tiles re-stream
    early blocks but stop at their OWN extent.  benchmarks/kernel_bench.py
    pins this model against the gather path's O(table width) and
    kernels/autotune.py feeds it to the roofline sanity bound."""
    qt = chunk if q_tile <= 0 else min(q_tile, chunk)
    nqt = -(-chunk // qt)
    total = 0
    for t in range(nqt):
        tile_last = min((t + 1) * qt, chunk)  # queries in the chunk tail
        tile_hi = kv_hi - chunk + tile_last  # absolute last position + 1
        total += -(-tile_hi // block_size)
    return total


def paged_attention(
    q,
    k,
    v,
    block_tables,
    qpos,
    *,
    scale: float,
    block_size: int,
    softcap: float = 0.0,
    num_splits: int = 0,
    q_tile: int = 0,
    interpret: bool = False,
    k_scale=None,
    v_scale=None,
):
    """Attention of q against a paged KV pool, through the block table.

    q: (B, Q, Hq, hd) — Q >= 1 query tokens per row (decode Q=1,
        speculative verify Q=K+1, prefill chunks Q=chunk).
    k, v: (Hkv, num_blocks * block_size, hd) physical pool; block ``b``
        owns pool slots [b*bs, (b+1)*bs).
    block_tables: (B, M) int32 logical -> physical block ids (M may be any
        host-sliced width covering every block the rows use).
    qpos: (B, Q) int32 absolute position of each query token; -1 marks
        padding / inactive rows (their output is 0 — callers never read it).
    num_splits: split-K parallelism (0 = auto); long rows fan out over the
        grid and partials merge host-side in ``_combine_splits``.
    q_tile: queries resident per VMEM tile (0 = all Q in one tile — the
        decode default).  Smaller tiles bound VMEM for long prefill chunks
        and sharpen the ragged early exit (a tile of early chunk positions
        stops streaming KV at its own extent); the output is invariant to
        the choice (tests/test_autotune.py) — kernels/autotune.py picks it
        per (arch, occupancy bucket, phase).
    k_scale, v_scale: (Hkv, num_blocks * block_size) float32 per-(token,
        head) dequant scales for int8 pools (both or neither).  Scale tiles
        ride the same block-table translation as the KV tiles and the
        dequant multiply happens in VMEM — int8 bytes stream from HBM, not
        a dequantized fp image (DESIGN.md §KV memory tiers).

    Returns (B, Q, Hq, hd) in q.dtype.
    """
    quant = k_scale is not None
    if quant != (v_scale is not None):
        raise ValueError("int8 pools need both k_scale and v_scale")
    b, nq, hq, hd = q.shape
    hkv, n_tok, _ = k.shape
    group = hq // hkv
    qt = nq if q_tile <= 0 else min(q_tile, nq)
    nqt = -(-nq // qt)
    qpad = nqt * qt - nq
    if qpad:
        # padded queries run at position -1: masked out of every KV tile,
        # their output rows are sliced off before returning
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, qpad)), constant_values=-1)
    qtg = qt * group
    m = block_tables.shape[1]
    if num_splits <= 0:
        # enough splits that short grids still spread, never more than the
        # table has blocks
        num_splits = max(1, min(4, m // 2))
    ns = min(num_splits, m)
    bps = -(-m // ns)
    pad = ns * bps - m
    if pad:
        # padded logical blocks index past every row's last position, so
        # the in_range guard skips them (entry 0 keeps the index_map safe)
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pad)))
    kp = k.reshape(hkv, n_tok // block_size, block_size, hd)
    vp = v.reshape(hkv, n_tok // block_size, block_size, hd)
    # (B, nqt, qt, Hkv, G, hd) -> (B*Hkv, nqt, qt*G, hd): the kv head is
    # grid-major, each q-tile's query group rides in one VMEM-resident tile
    qf = q.reshape(b, nqt, qt, hkv, group, hd).transpose(0, 3, 1, 2, 4, 5)
    qf = qf.reshape(b * hkv, nqt, qtg, hd)
    qpos_t = qpos.reshape(b, nqt, qt)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        softcap=softcap,
        block_size=block_size,
        group=group,
        blocks_per_split=bps,
        hkv=hkv,
        quant=quant,
    )

    def kv_map(c, t, s, j, bt, qp):
        # logical block (s * bps + j) of row (c // hkv) -> physical block
        return (c % hkv, bt[c // hkv, s * bps + j], 0, 0)

    def scale_map(c, t, s, j, bt, qp):
        return (c % hkv, bt[c // hkv, s * bps + j], 0)

    in_specs = [
        pl.BlockSpec((1, 1, qtg, hd), lambda c, t, s, j, bt, qp: (c, t, 0, 0)),
        pl.BlockSpec((1, 1, block_size, hd), kv_map),
        pl.BlockSpec((1, 1, block_size, hd), kv_map),
    ]
    inputs = [qf, kp, vp]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, block_size), scale_map)] * 2
        inputs += [
            k_scale.reshape(hkv, n_tok // block_size, block_size),
            v_scale.reshape(hkv, n_tok // block_size, block_size),
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, qpos (tiled (B, nqt, qt))
        grid=(b * hkv, nqt, ns, bps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, qtg),
                         lambda c, t, s, j, bt, qp: (c, t, s, 0)),
            pl.BlockSpec((1, 1, 1, qtg),
                         lambda c, t, s, j, bt, qp: (c, t, s, 0)),
            pl.BlockSpec((1, 1, 1, qtg, hd),
                         lambda c, t, s, j, bt, qp: (c, t, s, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qtg,), jnp.float32),  # running max
            pltpu.VMEM((qtg,), jnp.float32),  # running denominator
            pltpu.VMEM((qtg, hd), jnp.float32),  # output accumulator
        ],
    )
    ms, ls, accs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, nqt, ns, qtg), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, nqt, ns, qtg), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, nqt, ns, qtg, hd), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, qpos_t, *inputs)

    out = _combine_splits(
        ms.reshape(b * hkv * nqt, ns, qtg),
        ls.reshape(b * hkv * nqt, ns, qtg),
        accs.reshape(b * hkv * nqt, ns, qtg, hd),
    )
    out = out.reshape(b, hkv, nqt, qt, group, hd).transpose(0, 2, 3, 1, 4, 5)
    out = out.reshape(b, nqt * qt, hq, hd)
    return out[:, :nq].astype(q.dtype)
