"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
they execute in interpret mode, which runs the kernel body op-by-op in
Python — bit-faithful to the kernel's math, so the allclose tests against
kernels/ref.py validate the real TPU code path's semantics.

The model-facing signatures here adapt between the model's (B, S, H, hd)
tensors and the kernels' flattened-head layouts.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import rmsnorm as _rn
from repro.kernels import rwkv6 as _rw
from repro.kernels import ssm_scan as _ssm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("scale", "window", "softcap"))
def flash_attention(q, k, v, *, scale: float, window: int = 0,
                    softcap: float = 0.0):
    """Model-facing: q (B,S,Hq,hd); k,v (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    out = _fa.flash_attention(qf, kf, vf, scale=scale, window=window,
                              softcap=softcap, interpret=_interpret())
    return out.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("scale", "block_size", "softcap",
                                   "num_splits", "q_tile", "phase", "occ"))
def paged_attention(q, k, v, block_tables, positions, *, scale: float,
                    block_size: int, softcap: float = 0.0,
                    num_splits: int = 0, q_tile: int = 0,
                    phase: str = None, occ: float = 0.0,
                    k_scale=None, v_scale=None):
    """Model-facing: q (B, Q, Hq, hd) at per-query absolute `positions`
    (B, Q) (-1 = padding/inactive), against the paged pool k/v
    (Hkv, n_blocks*bs, hd) through `block_tables` (B, M).  Replaces the
    paged_view gather + _cached_attention read on the serving hot path —
    bytes-read scales with each row's actual kv length instead of the
    table width (kernels/paged_attention.py).  For int8 pools pass the
    per-(token, head) `k_scale`/`v_scale` arrays: tiles load as int8 and
    dequantize in VMEM (DESIGN.md §KV memory tiers).

    When `phase` is given ("decode"/"prefill"/"verify") and no explicit
    `num_splits`/`q_tile` override is set, the launch geometry comes from
    the committed tuning table (kernels/autotune.py; results/
    kernel_tuning.json) keyed by (arch, phase, occupancy bucket `occ`),
    falling back to the deterministic defaults on a missing key."""
    if phase is not None and num_splits == 0 and q_tile == 0:
        from repro.kernels import autotune as _at
        tuned = _at.get_config(phase, occ or 1.0, block_size=block_size)
        num_splits, q_tile = tuned.num_splits, tuned.q_tile
    return _pa.paged_attention(q, k, v, block_tables, positions,
                               scale=scale, block_size=block_size,
                               softcap=softcap, num_splits=num_splits,
                               q_tile=q_tile,
                               k_scale=k_scale, v_scale=v_scale,
                               interpret=_interpret())


@partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, weight, *, eps: float = 1e-5):
    return _rn.rmsnorm(x, weight, eps=eps, interpret=_interpret())


@partial(jax.jit, static_argnames=("eps",))
def rmsnorm_dequant(x, images, scales, weight, *, eps: float = 1e-5):
    """Fused dequant-sum + RMSNorm over a deferred int8 AllReduce
    (parallel/overlap.PendingResidual): one HBM pass instead of
    round-tripping the summed f32 activation (kernels/rmsnorm.py)."""
    return _rn.rmsnorm_dequant(x, images, scales, weight, eps=eps,
                               interpret=_interpret())


@jax.jit
def ssm_scan(x, b_mat, c_mat, dt, a_log):
    return _ssm.ssm_scan(x, b_mat, c_mat, dt, a_log,
                         interpret=_interpret())


@jax.jit
def rwkv6(r, k, v, w, u, s0=None):
    """Matches models.rwkv.wkv6_scan's signature (zero initial state only
    in the kernel; a nonzero s0 falls back to the scan path)."""
    y, s_last = _rw.rwkv6(r, k, v, w, u, interpret=_interpret())
    if s0 is not None:
        # kernel assumes zero state; fold a nonzero s0 analytically:
        # contribution of s0 to y_t is (prod_{tau<=t-1} w_tau) s0 . r_t —
        # cheap closed form, keeps the kernel simple
        wf = w.astype(jnp.float32)
        cw = jnp.cumprod(wf, axis=1)
        prev = jnp.concatenate([jnp.ones_like(cw[:, :1]),
                                cw[:, :-1]], axis=1)      # (B,S,H,hd)
        extra = jnp.einsum("bshi,bhij,bshi->bshj",
                           prev, s0, r.astype(jnp.float32))
        y = (y.astype(jnp.float32) + extra).astype(y.dtype)
        s_last = s_last + s0 * cw[:, -1][..., None]
    return y, s_last
