"""Pallas TPU flash attention (causal, sliding-window, GQA via index_map).

Grid (BH_q, nQ, nK) with the K dimension minor: the online-softmax
statistics (m, l) and the output accumulator live in VMEM scratch across the
K iterations of one (head, q-block) cell.  GQA needs NO materialised KV
repetition — the BlockSpec index_map divides the q-head index by the group
size so each q head streams its kv head's tiles straight from HBM.

Block shapes default to (128, head_dim) q-tiles and (512, head_dim) k-tiles:
q/k/v tiles plus the fp32 accumulator for head_dim 128 total ~0.7 MB —
comfortably inside VMEM, MXU-aligned on both matmul dims.  Fully-masked
tiles (strictly above the causal diagonal or outside the sliding window)
are skipped with pl.when, so the streamed work matches the useful work.

Validated in interpret mode against kernels/ref.flash_attention_ref over a
shape/dtype sweep (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, window: int, softcap: float, block_q: int,
            block_k: int, n_k: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_off = qi * block_q
    k_off = ki * block_k
    # tile-level early out: strictly above the diagonal / outside the window
    in_range = k_off <= q_off + block_q - 1
    if window:
        in_range = jnp.logical_and(
            in_range, k_off + block_k - 1 >= q_off - window + 1)

    @pl.when(in_range)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_off + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        kpos = k_off + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = (kpos <= qpos) & (kpos < seq_len)
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-37)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, scale: float, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 512, interpret: bool = False):
    """q: (BH, S, hd); k, v: (BHkv, S, hd) with BH = BHkv * g.  Causal."""
    bh, s, hd = q.shape
    bhkv = k.shape[0]
    g = bh // bhkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    n_q = -(-s // block_q)
    n_k = -(-s // block_k)
    pad_q = n_q * block_q - s
    pad_k = n_k * block_k - s
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, n_k=n_k, seq_len=s)

    out = pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n_q * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denominator
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
