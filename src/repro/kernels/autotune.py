"""Kernel autotuner: measured search over paged-attention launch configs.

PR 4's kernel shipped with fixed heuristics — ``num_splits = min(4, W//2)``
and the whole chunk resident as one q-tile.  TokenWeave (PAPERS.md) shows
the compute/comm split must be tuned per shape, not hardcoded; the same
holds for the kernel's own geometry.  This module sweeps

    (block_size, num_splits, q_tile)

per **(arch, occupancy bucket, phase)** against measured step time of the
same jitted read ``benchmarks/kernel_bench.py`` times, sanity-checks every
winner against the roofline bytes/FLOPs bound
(``launch.roofline.kernel_time_bound_s`` — a measurement that beats the
bound is noise, not a tuning, and is rejected), and persists winners in a
committed table ``results/kernel_tuning.json``.

Key space
---------
* **arch** — ``tpu-<device kind>`` on TPU, ``<platform>-interpret``
  elsewhere (interpret-mode timings are only meaningful relative to each
  other on the same host; a TPU looks up its own keys and falls back to
  the deterministic defaults when the committed table was swept on
  another arch).
* **occupancy bucket** — the block-table width the engine hands the step,
  as a fraction of ``max_blocks``, snapped up to {0.125, 0.25, 0.5, 1.0}
  (the same power-of-two bucketing ``scheduler._bt_width`` applies, so one
  jit variant per bucket resolves to one table entry).
* **phase** — ``decode`` (Q=1), ``verify`` (speculative K+1), ``prefill``
  (chunked prompt append).  ``q_tile`` only moves bytes for Q > 1, so the
  decode sweep pins it at 0.

Fallback is **deterministic**: a missing key (or a missing/invalid table)
resolves to ``default_config`` — exactly the pre-autotuner heuristics —
so tuned-off and missing-table behave identically
(tests/test_autotune.py pins this).

Consumers: ``kernels.ops.paged_attention`` (per-call ``phase``/``occ``)
and ``serving.engine.build_paged_steps`` (per-step static lookup at trace
time).  Regenerate with ``launch/serve.py --autotune`` or::

    PYTHONPATH=src python -m repro.kernels.autotune --sweep \
        --out results/kernel_tuning.json

The nightly CI job (``--check``) re-measures each committed geometry
head-to-head against the deterministic default on the runner and fails
if the tuned choice runs > 10% slower — the harm a stale table actually
causes (absolute fresh-vs-committed times would compare different hosts,
and fresh-sweep wins suffer the sweep's argmin selection bias).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import paged_attention as _pa

PHASES = ("decode", "prefill", "verify")
OCC_BUCKETS = (0.125, 0.25, 0.5, 1.0)
TABLE_VERSION = 1
TABLE_PATH = Path(__file__).resolve().parents[3] / "results" / \
    "kernel_tuning.json"

#: queries per phase in the sweep cases (decode=1, speculative K+1=4,
#: prefill chunk matches the engine's default bucket floor)
PHASE_Q = dict(decode=1, verify=4, prefill=16)


@dataclass(frozen=True)
class KernelConfig:
    """One paged-attention launch configuration.

    ``num_splits = 0`` / ``q_tile = 0`` mean "kernel auto": the in-kernel
    heuristics (``max(1, min(4, W // 2))`` splits, whole Q in one tile).
    ``block_size`` is advisory — the pool's block size is fixed at
    allocation, so it only takes effect where the caller owns the pool
    (engine startup, the sweep itself)."""

    block_size: int = 8
    num_splits: int = 0
    q_tile: int = 0


def default_config(phase: str = "decode", block_size: int = 8) -> KernelConfig:
    """The deterministic fallback: pre-autotuner heuristics, any phase."""
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; expected one of {PHASES}")
    return KernelConfig(block_size=block_size, num_splits=0, q_tile=0)


def arch_key() -> str:
    d = jax.devices()[0]
    if d.platform == "tpu":
        return "tpu-" + d.device_kind.lower().replace(" ", "-")
    return f"{d.platform}-interpret"


def occupancy_bucket(occ: float) -> str:
    """Snap an occupancy fraction UP to the sweep's bucket grid (matching
    the engine's power-of-two width bucketing)."""
    for b in OCC_BUCKETS:
        if occ <= b + 1e-9:
            return str(b)
    return str(OCC_BUCKETS[-1])


def entry_key(arch: str, phase: str, occ: float) -> str:
    return f"{arch}/{phase}/occ{occ_label(occ)}"


def occ_label(occ) -> str:
    return occ if isinstance(occ, str) else occupancy_bucket(float(occ))


# ---------------------------------------------------------------------------
# table persistence + validation
# ---------------------------------------------------------------------------

_ENTRY_INT_FIELDS = ("block_size", "num_splits", "q_tile")
_ENTRY_FLOAT_FIELDS = ("tuned_us", "default_us", "bound_us")


def validate_table(table: dict) -> None:
    """Schema check; raises ValueError with the offending key.  A table
    that fails here is treated as absent (deterministic fallback) by
    ``load_table`` callers that pass ``strict=False``."""
    if not isinstance(table, dict) or table.get("version") != TABLE_VERSION:
        raise ValueError(f"kernel tuning table: version != {TABLE_VERSION}")
    entries = table.get("entries")
    if not isinstance(entries, dict):
        raise ValueError("kernel tuning table: 'entries' mapping missing")
    for key, e in entries.items():
        parts = key.split("/")
        if len(parts) != 3 or parts[1] not in PHASES or \
                not parts[2].startswith("occ"):
            raise ValueError(f"kernel tuning table: malformed key {key!r}")
        for f in _ENTRY_INT_FIELDS:
            if not isinstance(e.get(f), int) or e[f] < 0:
                raise ValueError(
                    f"kernel tuning table: {key}: bad field {f!r}")
        if e["block_size"] < 1:
            raise ValueError(f"kernel tuning table: {key}: block_size < 1")
        for f in _ENTRY_FLOAT_FIELDS:
            if not isinstance(e.get(f), (int, float)) or e[f] < 0:
                raise ValueError(
                    f"kernel tuning table: {key}: bad field {f!r}")
        if e["tuned_us"] > e["default_us"] + 1e-9:
            # the default config is always in the candidate set, so a
            # recorded winner can never be slower than it
            raise ValueError(
                f"kernel tuning table: {key}: tuned_us > default_us")
        if e["tuned_us"] < e["bound_us"] - 1e-9:
            raise ValueError(
                f"kernel tuning table: {key}: tuned_us beats the roofline "
                "bound (measurement noise committed as a tuning)")


def load_table(path: Optional[Path] = None, *, strict: bool = True) -> dict:
    """Load + validate a tuning table.  strict=False returns {} on a
    missing or invalid file — the deterministic-fallback contract."""
    path = Path(path) if path is not None else TABLE_PATH
    try:
        table = json.loads(path.read_text())
        validate_table(table)
        return table
    except (OSError, ValueError, json.JSONDecodeError):
        if strict:
            raise
        return {}


def save_table(table: dict, path: Optional[Path] = None) -> Path:
    """Atomic write: scratch ``*.tmp.json`` sibling, then rename — the
    committed baseline is never left half-written."""
    validate_table(table)
    path = Path(path) if path is not None else TABLE_PATH
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp.json")
    tmp.write_text(json.dumps(table, indent=1, sort_keys=True) + "\n")
    os.replace(tmp, path)
    return path


@functools.lru_cache(maxsize=1)
def get_table() -> dict:
    """The committed table, loaded once ({} when absent/invalid).  After
    re-sweeping in-process (``serve.py --autotune``) call
    ``get_table.cache_clear()`` to pick up the fresh file."""
    return load_table(strict=False)


def get_config(phase: str, occ: float = 1.0, *, table: Optional[dict] = None,
               arch: Optional[str] = None,
               block_size: int = 8) -> KernelConfig:
    """Tuning lookup with deterministic fallback.

    occ: block-table width handed to the step / max_blocks (the engine's
    static per-jit-variant occupancy).  Missing key, missing table, or an
    entry swept for another arch all resolve to ``default_config`` —
    tuned-off and missing-table are indistinguishable by construction."""
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; expected one of {PHASES}")
    table = get_table() if table is None else table
    entries = table.get("entries", {}) if isinstance(table, dict) else {}
    e = entries.get(entry_key(arch or arch_key(), phase, occ))
    if e is None:
        return default_config(phase, block_size=block_size)
    return KernelConfig(block_size=e["block_size"],
                        num_splits=e["num_splits"], q_tile=e["q_tile"])


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def _time_fn(fn, *args, iters: int) -> float:
    jax.block_until_ready(fn(*args))  # compile outside the clock
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _phase_case(phase: str, occ: float, block_size: int, *, rows: int,
                hkv: int, group: int, hd: int, max_blocks: int, seed: int = 0):
    """Build (q, k, v, bt, qpos, kv_lens) for one sweep cell.  Prefill is
    one row appending a chunk whose last position lands at occ * s_max;
    decode/verify are ``rows`` uniform rows at that kv length."""
    s_max = max_blocks * block_size
    kv = max(PHASE_Q[phase], int(round(occ * s_max)))
    b = 1 if phase == "prefill" else rows
    nq = PHASE_Q[phase]
    hq = hkv * group
    key = jax.random.key(seed)
    q = jax.random.normal(key, (b, nq, hq, hd), jnp.float32)
    num_blocks = b * max_blocks
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (hkv, num_blocks * block_size, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (hkv, num_blocks * block_size, hd), jnp.float32)
    rng = np.random.default_rng(seed)
    bt = rng.permutation(num_blocks).reshape(b, max_blocks)
    used = -(-kv // block_size)
    from repro.serving.scheduler import _bucket
    w = min(_bucket(used, 1), max_blocks)
    bt = jnp.asarray(bt[:, :w], jnp.int32)
    # queries sit at the TAIL of the kv extent (decode: the last position;
    # verify/prefill: the last nq positions — the append shape)
    qpos = jnp.broadcast_to(jnp.arange(kv - nq, kv, dtype=jnp.int32)[None],
                            (b, nq))
    return q, k, v, bt, qpos, [kv] * b


def _case_bytes(phase: str, kv_lens, nq: int, block_size: int, q_tile: int,
                hkv: int, hd: int, isize: int = 4) -> int:
    """Analytical HBM KV bytes for one step of this config — the roofline
    numerator and the prefill bytes model kernel_bench gates."""
    from repro.serving.kv_cache import kv_block_bytes
    per_block = kv_block_bytes(block_size, hkv, hd, isize)
    if phase == "prefill":
        return sum(_pa.prefill_kernel_blocks(kv, nq, q_tile, block_size)
                   for kv in kv_lens) * per_block
    # decode/verify: every q-tile of every row streams up to its row's
    # extent; Q is small so tiles share the extent
    nqt = 1 if q_tile <= 0 else -(-nq // min(q_tile, nq))
    return sum(-(-kv // block_size) for kv in kv_lens) * per_block * nqt


def _case_flops(kv_lens, nq: int, hq: int, hd: int) -> float:
    # qk + pv per row: 2 * (Q*Hq*hd*kv) each
    return float(sum(4.0 * nq * hq * hd * kv for kv in kv_lens))


def _candidates(phase: str, nq: int):
    splits = [0, 2, 4]
    q_tiles = [0] if nq == 1 else [0, 4]
    return [(ns, qt) for ns in splits for qt in q_tiles
            if qt <= nq or qt == 0]


def sweep(*, block_sizes=(8, 16), rows: int = 4, hkv: int = 2,
          group: int = 2, hd: int = 32, max_blocks: int = 16,
          iters: int = 3, min_win: Optional[float] = None,
          arch: Optional[str] = None,
          interpret: Optional[bool] = None, verbose: bool = True) -> dict:
    """Run the full (phase x occupancy x candidate) sweep; returns a
    tuning table dict (not yet persisted).

    Winners are argmin of measured median step time over the candidate
    set; the default config is ALWAYS a candidate, so ``tuned_us <=
    default_us`` holds by construction on every entry (check_bench gates
    it).  Candidates measuring below the roofline bound are rejected as
    noise before the argmin.  A non-default winner must then CONFIRM its
    win in an independent head-to-head re-measurement against the default
    by at least ``min_win`` — the argmin over noisy medians is biased low
    (winner's curse), and without confirmation a noise win gets committed
    and the nightly ``--check`` re-measurement flags it.  Confirmed
    entries record the confirmation-run times (unbiased), not the
    argmin's.  ``min_win`` defaults above the check tolerance on
    interpret backends (0.15; timing noise there can erase a marginal
    win between sweep and check) and to 0.05 compiled."""
    arch = arch or arch_key()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if min_win is None:
        min_win = 0.15 if interpret else 0.05
    entries = {}
    from repro.launch.roofline import kernel_time_bound_s
    for phase in PHASES:
        nq = PHASE_Q[phase]
        for occ in OCC_BUCKETS:
            best = None
            default_us = None
            bound_floor = None
            for bs in block_sizes:
                q, k, v, bt, qpos, kv_lens = _phase_case(
                    phase, occ, bs, rows=rows, hkv=hkv, group=group, hd=hd,
                    max_blocks=max_blocks)
                scale = hd ** -0.5
                for ns, qt in _candidates(phase, nq):
                    cfg = KernelConfig(block_size=bs, num_splits=ns,
                                       q_tile=qt)
                    call = jax.jit(functools.partial(
                        _pa.paged_attention, scale=scale, block_size=bs,
                        num_splits=ns, q_tile=qt, interpret=interpret))
                    t_us = _time_fn(call, q, k, v, bt, qpos,
                                    iters=iters) * 1e6
                    byt = _case_bytes(phase, kv_lens, nq, bs, qt, hkv, hd)
                    bound_us = kernel_time_bound_s(
                        byt, _case_flops(kv_lens, nq, hkv * group, hd)) * 1e6
                    is_default = (bs == block_sizes[0] and ns == 0
                                  and qt == 0)
                    if is_default:
                        default_us = t_us
                        bound_floor = bound_us
                    if t_us < bound_us:
                        # faster than the hardware allows: noise, reject
                        if verbose:
                            print(f"  reject {phase}/occ{occ}/{cfg}: "
                                  f"{t_us:.1f}us beats bound "
                                  f"{bound_us:.1f}us")
                        continue
                    if best is None or t_us < best[0]:
                        best = (t_us, cfg, bound_us)
            if best is None:
                # every candidate beat the bound (pathological clock):
                # keep the deterministic default, quote the bound itself
                best = (bound_floor or 0.0,
                        default_config(phase, block_size=block_sizes[0]),
                        bound_floor or 0.0)
            t_us, cfg, bound_us = best
            # the winner's time must be quoted against a default measured
            # with the same protocol; if the default itself was rejected
            # as noise, quote the winner (tuned == default, no regression)
            d_us = default_us if default_us is not None else t_us
            if t_us > d_us:
                # the default won (or tied modulo rejection): record it so
                # tuned <= default holds exactly
                t_us, cfg, bound_us = d_us, default_config(
                    phase, block_size=block_sizes[0]), bound_floor
            d_cfg = default_config(phase, block_size=block_sizes[0])
            if cfg != d_cfg:
                # confirmation run (argmin-bias guard): the winner's
                # argmin time is biased low, so re-measure winner and
                # default head-to-head and keep the win only if it
                # survives with the min_win margin; record the
                # confirmation times (unbiased) on success
                t2 = _measure_cfg(phase, occ, cfg, rows=rows, hkv=hkv,
                                  group=group, hd=hd,
                                  max_blocks=max_blocks, iters=iters,
                                  interpret=interpret)
                d2 = _measure_cfg(phase, occ, d_cfg, rows=rows, hkv=hkv,
                                  group=group, hd=hd,
                                  max_blocks=max_blocks, iters=iters,
                                  interpret=interpret)
                if t2 <= d2 * (1.0 - min_win):
                    t_us, d_us = t2, d2
                else:
                    if verbose:
                        print(f"  unconfirmed {phase}/occ{occ}/"
                              f"{asdict(cfg)}: {t2:.1f}us vs default "
                              f"{d2:.1f}us on re-measure; keeping default")
                    t_us, cfg, bound_us = d2, d_cfg, bound_floor
                    d_us = d2
            entries[entry_key(arch, phase, occ)] = dict(
                block_size=cfg.block_size, num_splits=cfg.num_splits,
                q_tile=cfg.q_tile, tuned_us=round(t_us, 1),
                default_us=round(d_us, 1),
                bound_us=round(min(bound_us, t_us, d_us), 3))
            if verbose:
                print(f"tuned {phase}/occ{occ}: {asdict(cfg)} "
                      f"{t_us:.1f}us (default {d_us:.1f}us, "
                      f"bound {bound_us:.3f}us)")
    return dict(version=TABLE_VERSION, arch=arch,
                swept=dict(block_sizes=list(block_sizes), rows=rows,
                           kv_heads=hkv, group=group, head_dim=hd,
                           max_blocks=max_blocks, iters=iters,
                           interpret=interpret),
                entries=entries)


def _measure_cfg(phase: str, occ: float, cfg: KernelConfig, *, rows: int,
                 hkv: int, group: int, hd: int, max_blocks: int, iters: int,
                 interpret: bool) -> float:
    """Median step time (us) of one launch config on its sweep case."""
    q, k, v, bt, qpos, _ = _phase_case(
        phase, occ, cfg.block_size, rows=rows, hkv=hkv, group=group, hd=hd,
        max_blocks=max_blocks)
    call = jax.jit(functools.partial(
        _pa.paged_attention, scale=hd ** -0.5, block_size=cfg.block_size,
        num_splits=cfg.num_splits, q_tile=cfg.q_tile, interpret=interpret))
    return _time_fn(call, q, k, v, bt, qpos, iters=iters) * 1e6


def check_regression(committed: dict, *, tol: float = 0.10, iters: int = 3,
                     interpret: Optional[bool] = None) -> int:
    """Nightly gate: re-measure each committed cell's tuned geometry
    head-to-head against the deterministic default ON THIS HOST, and fail
    if the tuned choice runs more than ``tol`` slower than the default —
    the harm a stale table actually causes.  Two comparisons this gate
    deliberately does NOT make: fresh-vs-committed absolute times (the
    nightly runner is not the machine that swept the table), and
    fresh-sweep-win vs committed-win (the committed ``tuned_us`` is an
    argmin over noisy medians, biased low — an unbiased re-measurement
    reads as erosion even when nothing changed).  Cells whose committed
    geometry IS the default pass without measuring (a config can't lose
    to itself; re-timing it twice would just race the clock).  Returns
    the failure count."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    swept = committed.get("swept", {})
    geom = dict(rows=swept.get("rows", 4), hkv=swept.get("kv_heads", 2),
                group=swept.get("group", 2), hd=swept.get("head_dim", 32),
                max_blocks=swept.get("max_blocks", 16))
    d_bs = swept.get("block_sizes", [8])[0]
    failures = 0
    for key, e in sorted(committed.get("entries", {}).items()):
        _, phase, occ_s = key.split("/")
        occ = float(occ_s[len("occ"):])
        cfg = KernelConfig(block_size=e["block_size"],
                           num_splits=e["num_splits"], q_tile=e["q_tile"])
        if cfg == default_config(phase, block_size=d_bs):
            print(f"ok   kernel_tuning/{key}: committed geometry is the "
                  f"default")
            continue
        t_us = _measure_cfg(phase, occ, cfg, iters=iters,
                            interpret=interpret, **geom)
        d_us = _measure_cfg(phase, occ, default_config(phase, block_size=d_bs),
                            iters=iters, interpret=interpret, **geom)
        ceil_us = d_us * (1.0 + tol)
        ok = t_us <= ceil_us
        print(f"{'ok  ' if ok else 'FAIL'} kernel_tuning/{key}: committed "
              f"geometry {t_us:.1f}us vs default {d_us:.1f}us "
              f"(ceil {ceil_us:.1f}us)")
        failures += 0 if ok else 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--sweep", action="store_true",
                    help="run the sweep and write --out")
    ap.add_argument("--check", action="store_true",
                    help="re-measure the committed table's geometries "
                         "head-to-head vs the defaults and fail on > "
                         "--tol regression (the nightly job)")
    ap.add_argument("--out", default=str(TABLE_PATH))
    ap.add_argument("--tol", type=float, default=0.10)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    if not (args.sweep or args.check):
        ap.error("need --sweep or --check")
    if args.check:
        committed = load_table(Path(args.out))
        failures = check_regression(committed, tol=args.tol,
                                    iters=args.iters)
        print(f"{failures} tuning regression(s)" if failures else
              "tuning within tolerance of committed table")
        return 1 if failures else 0
    fresh = sweep(iters=args.iters)
    out = save_table(fresh, Path(args.out))
    print(f"wrote {len(fresh['entries'])} entries -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
