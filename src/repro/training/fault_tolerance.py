"""Fault tolerance: heartbeats, straggler detection, restart policy.

On a real multi-pod deployment every host runs this monitor beside the
training loop.  The mechanisms are host-level (files + wall clock), so they
work identically in the single-process simulation used by the tests:

* Heartbeat: each host touches ``<dir>/hb-<host>`` every step.  A host whose
  heartbeat is older than ``dead_after_s`` is declared failed; the controller
  responds by triggering checkpoint-restart with the surviving host set
  (elastic: the mesh is rebuilt via launch.mesh.make_mesh_for and the
  checkpoint reshards on load — arrays are stored unsharded).

* Straggler detection: an EMA of step time per host; a host slower than
  ``straggler_factor`` x the fleet median for ``patience`` consecutive steps
  is flagged.  Policy hooks: "report" (default), "exclude" (treat as failed
  -> elastic restart without it), mirroring TPU fleet practice where a
  degraded host is drained rather than load-balanced around (SPMD steps are
  lockstep — one slow host stalls every chip, so exclusion is the only
  effective mitigation).

* Restart budget: crash-looping guard — at most ``max_restarts`` within
  ``window_s`` seconds.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional


@dataclass
class FTConfig:
    dead_after_s: float = 60.0
    straggler_factor: float = 1.5
    patience: int = 5
    max_restarts: int = 10
    window_s: float = 3600.0
    policy: str = "exclude"          # report | exclude


class Heartbeat:
    def __init__(self, directory: str, host: str):
        self.path = Path(directory) / f"hb-{host}"
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int):
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(dict(step=step, time=time.time())))
        tmp.replace(self.path)

    @staticmethod
    def scan(directory: str, dead_after_s: float,
             now: Optional[float] = None) -> Dict[str, bool]:
        """host -> alive?"""
        now = now if now is not None else time.time()
        out = {}
        for p in Path(directory).glob("hb-*"):
            host = p.name[3:]
            try:
                t = json.loads(p.read_text())["time"]
            except Exception:
                out[host] = False
                continue
            out[host] = (now - t) < dead_after_s
        return out


class StragglerMonitor:
    """Per-host step-time EMA vs fleet median."""

    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.ema: Dict[str, float] = {}
        self.strikes: Dict[str, int] = {}

    def observe(self, host: str, step_time_s: float):
        prev = self.ema.get(host, step_time_s)
        self.ema[host] = 0.9 * prev + 0.1 * step_time_s

    def flagged(self) -> List[str]:
        if len(self.ema) < 2:
            return []
        times = sorted(self.ema.values())
        median = times[len(times) // 2]
        out = []
        for host, t in self.ema.items():
            if t > self.cfg.straggler_factor * median:
                self.strikes[host] = self.strikes.get(host, 0) + 1
            else:
                self.strikes[host] = 0
            if self.strikes.get(host, 0) >= self.cfg.patience:
                out.append(host)
        return out


class RestartBudget:
    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.events: List[float] = []

    def allow(self, now: Optional[float] = None) -> bool:
        now = now if now is not None else time.time()
        self.events = [t for t in self.events
                       if now - t < self.cfg.window_s]
        if len(self.events) >= self.cfg.max_restarts:
            return False
        self.events.append(now)
        return True


@dataclass
class FleetController:
    """Decides the surviving host set after failures/stragglers.

    ``plan_restart`` returns the new world size (hosts x chips_per_host) to
    hand to launch.mesh.make_mesh_for — the elastic-scaling entry point.
    """

    cfg: FTConfig
    hosts: List[str]
    chips_per_host: int = 8

    def plan_restart(self, hb_dir: str,
                     stragglers: Optional[List[str]] = None,
                     now: Optional[float] = None):
        alive = Heartbeat.scan(hb_dir, self.cfg.dead_after_s, now=now)
        survivors = [h for h in self.hosts if alive.get(h, False)]
        if self.cfg.policy == "exclude":
            for s in (stragglers or []):
                if s in survivors and len(survivors) > 1:
                    survivors.remove(s)
        return dict(survivors=survivors,
                    world=len(survivors) * self.chips_per_host,
                    lost=[h for h in self.hosts if h not in survivors])
