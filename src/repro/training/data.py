"""Data pipeline: deterministic, shardable, restart-safe token streams.

Two sources:
* SyntheticLM — structured pseudo-language (Zipfian unigrams + local
  n-gram structure) so tiny models show decreasing loss; fully deterministic
  in (seed, step), which makes checkpoint-restart bitwise reproducible
  WITHOUT persisting reader state.
* PackedCorpus — memory-mapped uint16/uint32 token file, sequence-packed,
  sharded by (host, step) the same deterministic way.

The global batch for step ``t`` is a pure function of (seed, t): after a
restart the loader resumes from the checkpointed step with no drift, and a
re-sharded (elastic) job reads exactly the same global batch split
differently — the foundation of the fault-tolerance story.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        # Zipfian unigram field
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        base = rng.choice(v, size=(b, s), p=probs)
        # inject learnable local structure: token[i+1] == f(token[i]) often
        follow = (base[:, :-1] * 31 + 7) % v
        mask = rng.random((b, s - 1)) < 0.5
        tokens = base.copy()
        tokens[:, 1:][mask] = follow[mask]
        targets = np.roll(tokens, -1, axis=1)
        targets[:, -1] = 0
        return dict(tokens=tokens.astype(np.int32),
                    targets=targets.astype(np.int32))

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class PackedCorpus:
    """Flat token file, packed into fixed-length training sequences."""

    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        self._tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_seqs = (len(self._tokens) - 1) // self.seq_len
        if self._n_seqs < 1:
            raise ValueError(f"corpus too small: {len(self._tokens)} tokens")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        idx = rng.integers(0, self._n_seqs, size=(self.global_batch,))
        toks = np.stack([
            self._tokens[i * self.seq_len:(i + 1) * self.seq_len + 1]
            for i in idx]).astype(np.int32)
        toks = np.clip(toks, 0, self.vocab_size - 1)
        return dict(tokens=toks[:, :-1], targets=toks[:, 1:])

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_loader(kind: str, **kw):
    if kind == "synthetic":
        return SyntheticLM(**kw)
    if kind == "packed":
        return PackedCorpus(**kw)
    raise ValueError(kind)
