"""Checkpointing: atomic, keep-k, elastic (mesh-shape independent).

Checkpoints store FULL (unsharded) arrays host-side as one ``.npz`` payload
per pytree plus a JSON manifest.  Because arrays are saved unsharded, a
restart may use a *different* mesh (elastic scaling after a node failure):
the launcher reshards on load via shard_map in_specs exactly as at init.

Write protocol: serialize to ``<dir>/tmp-<step>``, fsync, then atomically
rename to ``step-<step>`` — a crash mid-write never corrupts the latest
checkpoint.  ``keep`` oldest checkpoints are garbage-collected after a
successful rename, newest-first retention.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(proto, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(proto)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != "
                f"expected {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------
    def save(self, step: int, params, opt_state=None,
             extra: Optional[dict] = None) -> Path:
        tmp = self.dir / f"tmp-{step}"
        final = self.dir / f"step-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "params.npz", **_flatten(params))
        if opt_state is not None:
            np.savez(tmp / "opt_state.npz", **_flatten(opt_state))
        manifest = dict(step=step, time=time.time(), extra=extra or {})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # fsync the directory entries before the atomic publish
        for f in tmp.iterdir():
            fd = os.open(f, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step-{s}", ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.glob("step-*"):
            try:
                out.append(int(p.name.split("-", 1)[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, params_proto, opt_proto=None,
                step: Optional[int] = None) -> Tuple[int, Any, Any, dict]:
        """Returns (step, params, opt_state, extra).  Protos supply the
        pytree structure and dtypes (possibly under a NEW mesh layout —
        arrays are full-size so any layout reshards on the way in)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step-{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "params.npz") as z:
            params = _unflatten_like(params_proto, dict(z))
        opt_state = None
        if opt_proto is not None and (d / "opt_state.npz").exists():
            with np.load(d / "opt_state.npz") as z:
                opt_state = _unflatten_like(opt_proto, dict(z))
        return step, params, opt_state, manifest.get("extra", {})
