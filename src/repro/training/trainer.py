"""Training loop: sharded step + checkpointing + fault-tolerance hooks.

The Trainer is deliberately thin: all math lives in parallel/tp.py
(build_train_step) and optimizer.py; this class owns the run lifecycle —
resume, heartbeats, straggler monitoring, periodic checkpoints, metrics.
It runs identically on a 4-device test mesh and the 512-chip production
mesh (the step function is mesh-agnostic).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.parallel import compat
from repro.parallel import tp as tpmod
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import (FTConfig, Heartbeat,
                                            StragglerMonitor)


@dataclass
class TrainerState:
    step: int
    params: Any
    opt_state: Any


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, pcfg: ParallelConfig,
                 tcfg: TrainConfig, *, ckpt_dir: Optional[str] = None,
                 zero1: bool = False, fsdp: bool = False,
                 host: str = "host0", hb_dir: Optional[str] = None,
                 log: Callable[[str], None] = print):
        self.cfg, self.mesh, self.pcfg, self.tcfg = cfg, mesh, pcfg, tcfg
        self.zero1, self.fsdp = zero1, fsdp
        self.log = log
        step_fn, in_specs, _ = tpmod.build_train_step(
            cfg, mesh, pcfg, tcfg, zero1=zero1, fsdp=fsdp)
        self.in_specs = in_specs
        with compat.set_mesh(mesh):
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(ckpt_dir, keep=tcfg.keep_checkpoints) \
            if ckpt_dir else None
        self.hb = Heartbeat(hb_dir, host) if hb_dir else None
        self.straggler = StragglerMonitor(FTConfig())
        self.host = host

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0) -> TrainerState:
        params, opt_state, _ = tpmod.init_train_state(
            self.cfg, self.pcfg, jax.random.key(seed), zero1=self.zero1,
            fsdp=self.fsdp)
        if self.zero1:
            env = tpmod.make_axis_env(self.pcfg)
            seed_fn = compat.shard_map(
                lambda p, s: opt.zero1_seed_master(p, s, env),
                self.mesh,
                (self.in_specs[0], self.in_specs[1]),
                self.in_specs[1])
            with compat.set_mesh(self.mesh):
                opt_state = jax.jit(seed_fn)(params, opt_state)
        return TrainerState(0, params, opt_state)

    def resume_or_init(self, seed: int = 0) -> TrainerState:
        state = self.init_state(seed)
        if self.ckpt and self.ckpt.latest_step() is not None:
            step, params, opt_state, _ = self.ckpt.restore(
                state.params, state.opt_state)
            self.log(f"[trainer] resumed from step {step}")
            return TrainerState(step, params, opt_state)
        return state

    # ------------------------------------------------------------------
    def fit(self, state: TrainerState, loader, steps: int,
            on_metrics: Optional[Callable[[int, Dict], None]] = None
            ) -> TrainerState:
        tc = self.tcfg
        with compat.set_mesh(self.mesh):
            for local in range(steps):
                step = state.step
                batch = {k: jnp.asarray(v)
                         for k, v in loader.batch_at(step).items()}
                t0 = time.time()
                params, opt_state, metrics = self.step_fn(
                    state.params, state.opt_state, batch,
                    jnp.asarray(step, jnp.int32))
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                state = TrainerState(step + 1, params, opt_state)

                if self.hb:
                    self.hb.beat(step)
                self.straggler.observe(self.host, dt)

                if step % tc.log_every == 0 and on_metrics is None:
                    self.log(f"[trainer] step {step} loss={metrics['loss']:.4f} "
                             f"gnorm={metrics['grad_norm']:.3f} "
                             f"lr={metrics['lr']:.2e} {dt*1e3:.0f}ms")
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if self.ckpt and (step + 1) % tc.checkpoint_every == 0:
                    self.ckpt.save(step + 1, state.params, state.opt_state)
        return state
