"""Optimizers, built from scratch on pytrees (no optax dependency).

``adamw`` runs identically inside shard_map (states inherit the parameter
sharding: each device updates its slice with its gradient slice).

``sharded_adamw`` is the ZeRO-1 variant for the data axis: optimizer moments
live sharded across data-parallel ranks; each step does
reduce-scatter(grad) -> local moment update -> all-gather(param delta),
trading the DP all-reduce for the same bytes split as RS+AG while cutting
optimizer-state memory by dp.  Master weights are kept in fp32 when params
are bf16 (mixed-precision training discipline).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.parallel.collectives import AxisEnv


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Any          # fp32 master copy (None leaves if params are fp32)


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / per-head vectors."""
    names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
    last = names[-1] if names else ""
    nd_keys = {"norm", "final_norm", "norm_w", "ln_w", "w_bias", "dt_bias",
               "a_log", "d_skip", "u", "mu_r", "mu_k", "mu_v", "mu_g", "mu_w"}
    return last not in nd_keys


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(
        lambda p: p.astype(jnp.float32) if p.dtype != jnp.float32 else None,
        params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(grads, state: AdamWState, params, *, lr,
                 cfg: TrainConfig, masks=None):
    """One AdamW step.  Returns (new_params, new_state).

    grads may be lower precision; moments and master weights are fp32.
    masks: optional {0,1} pytree freezing padded-head weights.
    """
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_g, treedef = jax.tree_util.tree_flatten_with_path(grads)
    flat_p = jax.tree.leaves(params)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    flat_ma = jax.tree.leaves(state.master, is_leaf=lambda x: x is None)
    flat_mk = (jax.tree.leaves(masks) if masks is not None
               else [None] * len(flat_p))

    new_p, new_mu, new_nu, new_ma = [], [], [], []
    for (path, g), p, mu, nu, ma, mk in zip(flat_g, flat_p, flat_mu, flat_nu,
                                            flat_ma, flat_mk):
        gf = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * gf
        nu = b2 * nu + (1 - b2) * jnp.square(gf)
        mhat = mu / bc1
        nhat = nu / bc2
        upd = mhat / (jnp.sqrt(nhat) + 1e-8)
        w = ma if ma is not None else p.astype(jnp.float32)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * w
        w = w - lr * upd
        if mk is not None:
            w = w * mk.astype(w.dtype)
            mu = mu * mk.astype(mu.dtype)
            nu = nu * mk.astype(nu.dtype)
        new_mu.append(mu)
        new_nu.append(nu)
        new_ma.append(w if ma is not None else None)
        new_p.append(w.astype(p.dtype))

    unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    return unf(new_p), AdamWState(
        step=step, mu=unf(new_mu), nu=unf(new_nu),
        master=jax.tree_util.tree_unflatten(treedef, new_ma))


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer states sharded over the data axis
# ---------------------------------------------------------------------------

def _flat_size(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def zero1_init(params, pspecs, tp: int, dp: int) -> AdamWState:
    """GLOBAL zero-1 state arrays (host side, before sharding).

    Layout per leaf: the fp32 master/moments live as a permuted flat vector
    partitioned jointly over (model, data) for TP-sharded leaves — shape
    (tp * dp * chunk,) with spec P(("model","data")) — and over data only
    for replicated leaves — shape (dp * chunk,) with spec P("data").
    ``chunk = ceil(tp_local_size / dp)`` so each device holds exactly
    (chunk,) regardless of leaf kind.  The permutation is irrelevant:
    AdamW is elementwise and the gradient is partitioned identically by the
    in-step reduce-scatter.
    """
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import spec_has

    def mk(p, spec):
        sharded = spec_has(spec, "model")
        local = _flat_size(p.shape) // (tp if sharded else 1)
        chunk = -(-local // dp)
        n = (tp if sharded else 1) * dp * chunk
        return jnp.zeros((n,), jnp.float32)

    zeros = jax.tree.map(mk, params, pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros),
                      master=jax.tree.map(jnp.copy, zeros))


def zero1_seed_master(params, state: AdamWState, env: AxisEnv) -> AdamWState:
    """Populate master shards from the (replicated) params."""
    dp = env.dp

    def seed(p, _):
        n = -(-_flat_size(p.shape) // dp)
        flat = jnp.pad(p.astype(jnp.float32).reshape(-1),
                       (0, n * dp - _flat_size(p.shape)))
        i = env.data_axis_index()
        return jax.lax.dynamic_slice_in_dim(flat, i * n, n)
    return state._replace(master=jax.tree.map(seed, params, state.master))


def zero1_update(grads, state: AdamWState, params, *, lr, cfg: TrainConfig,
                 env: AxisEnv, masks=None):
    """ZeRO-1 AdamW step inside shard_map.

    grads: per-device *unreduced* DP gradients (the reduce-scatter performs
    the DP mean).  Returns (new_params, new_state).
    """
    dp = env.dp
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_g, treedef = jax.tree_util.tree_flatten_with_path(grads)
    flat_p = jax.tree.leaves(params)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    flat_ma = jax.tree.leaves(state.master)
    flat_mk = (jax.tree.leaves(masks) if masks is not None
               else [None] * len(flat_p))

    new_p, new_mu, new_nu, new_ma = [], [], [], []
    for (path, g), p, mu, nu, ma, mk in zip(flat_g, flat_p, flat_mu, flat_nu,
                                            flat_ma, flat_mk):
        n = mu.shape[0]
        gf = g.astype(jnp.float32).reshape(-1)
        gf = jnp.pad(gf, (0, n * dp - gf.shape[0]))
        # DP mean fused into the reduce-scatter
        if env.data:
            gsh = jax.lax.psum_scatter(gf, env.data, scatter_dimension=0,
                                       tiled=True) / dp
        else:
            gsh = gf
        if env.pod:
            gsh = jax.lax.pmean(gsh, env.pod)
        mu = b1 * mu + (1 - b1) * gsh
        nu = b2 * nu + (1 - b2) * jnp.square(gsh)
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + 1e-8)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * ma
        w = ma - lr * upd
        full = jax.lax.all_gather(w, env.data, tiled=True) if env.data else w
        full = full[:_flat_size(p.shape)].reshape(p.shape)
        if mk is not None:
            full = full * mk.astype(full.dtype)
        new_p.append(full.astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)
        new_ma.append(w)

    unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    return unf(new_p), AdamWState(step=step, mu=unf(new_mu), nu=unf(new_nu),
                                  master=unf(new_ma))


def lr_schedule(cfg: TrainConfig):
    """Cosine decay with linear warmup (the paper's §4.1 recipe)."""
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.learning_rate * warm * (cfg.min_lr / cfg.learning_rate +
                                           (1 - cfg.min_lr / cfg.learning_rate) * cos)
    return lr
