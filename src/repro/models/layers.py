"""Shared primitive layers (pure functions over param pytrees).

Conventions
-----------
* Parameters are nested dicts of ``jnp.ndarray``.  ``init_*`` functions build
  FULL (unsharded) parameters; the launch layer slices them via shard_map
  in_specs.  Inside shard_map the arrays arrive pre-sliced, and all layer
  code derives head counts / widths from the *actual* array shapes, so the
  same function body runs at TP=1 and TP=16.
* Linear layers keep weights as (in, out) and compute ``x @ w``.
* The AllReduce that completes a TP-partial output is NOT applied here; it is
  owned by the residual topology driver (core/residual.py) — that placement
  is the paper's contribution.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.collectives import AxisEnv


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-5, env: AxisEnv | None = None,
            use_pallas: bool = False):
    """RMSNorm over the feature axis.

    With SP the residual is seq-sharded (features full), so no cross-device
    reduction is needed here.  ``use_pallas`` dispatches to the fused Pallas
    kernel on TPU-shaped inputs.
    """
    if use_pallas:
        from repro.kernels import ops
        return ops.rmsnorm(x, weight, eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def rmsnorm_dequant(x, images, scales, weight, eps: float = 1e-5,
                    use_pallas: bool = False):
    """RMSNorm of ``x + sum_j dequant(images[j])`` — the fused consumer of a
    deferred int8 AllReduce (parallel/overlap.PendingResidual).

    The jnp path below is the bit-level oracle for the Pallas kernel
    (kernels/rmsnorm.rmsnorm_dequant): same f32 source-ordered
    dequant-accumulate, same norm math on the UN-downcast f32 sum — so
    under jit (how the engines run both paths) ``use_pallas`` on/off
    emit bit-identical activations and the serving engines' token
    streams match (tests/test_autotune.py pins it; eagerly the separate
    mul+add rounds twice where XLA fuses one FMA — 1-ulp slack).
    """
    if use_pallas:
        from repro.kernels import ops
        return ops.rmsnorm_dequant(x, images, scales, weight, eps=eps)
    acc = x.astype(jnp.float32)
    for j in range(images.shape[0]):
        acc = acc + images[j].astype(jnp.float32) * \
            scales[j].astype(jnp.float32)[..., None]
    var = jnp.mean(acc * acc, axis=-1, keepdims=True)
    y = acc * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def init_rmsnorm(d: int, dtype):
    # stored as (weight - 1) like gemma/llama "zero-centered" convention
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding (vocab-sharded over the model axis)
# ---------------------------------------------------------------------------

def embed_lookup(table, tokens, env: AxisEnv):
    """Vocab-sharded embedding lookup.

    ``table`` arrives with shape (vocab/tp, d_model) inside shard_map.  Each
    shard contributes rows it owns; a psum over the model axis completes the
    lookup.  (This psum is tiny — (B,S,D) bf16 — and is issued once at the
    stack entry, where the Ladder schedule cannot help; it is counted in the
    roofline's collective term.)
    """
    vshard = table.shape[0]
    idx = tokens - env.model_axis_index() * vshard
    ok = (idx >= 0) & (idx < vshard)
    x = jnp.where(ok[..., None], jnp.take(table, jnp.clip(idx, 0, vshard - 1),
                                          axis=0), 0)
    return env.psum_model(x)


def lm_head_logits(x, table):
    """Per-shard logits against a vocab-sharded (tied) embedding table.

    Returns vocab-sharded logits (B, S, vocab/tp); consumers use the sharded
    softmax in :func:`sharded_cross_entropy` so the full logits tensor is
    never materialised (a memory-roofline win for 200k+ vocabularies).
    """
    return x @ table.T.astype(x.dtype)


def sharded_cross_entropy(logits_shard, targets, env: AxisEnv,
                          z_loss: float = 0.0,
                          true_vocab: Optional[int] = None):
    """Stable cross-entropy over vocab-sharded logits.

    logits_shard: (B, S, V/tp) — this shard's slice of the vocab.
    targets: (B, S) global token ids.
    true_vocab: unpadded vocabulary size; padded columns are masked out of
    the softmax (Megatron-style), so padded embedding rows receive exactly
    zero gradient.
    Returns per-token negative log-likelihood (B, S) replicated over model.
    """
    vshard = logits_shard.shape[-1]
    lf = logits_shard.astype(jnp.float32)
    if true_vocab is not None:
        col = jnp.arange(vshard) + env.model_axis_index() * vshard
        lf = jnp.where(col < true_vocab, lf, -1e30)
    local_max = jnp.max(lf, axis=-1)
    if env.model:
        gmax = jnp.max(jax.lax.all_gather(local_max, env.model), axis=0)
    else:
        gmax = local_max
    gmax = jax.lax.stop_gradient(gmax)
    ex = jnp.exp(lf - gmax[..., None])
    denom = env.psum_model(jnp.sum(ex, axis=-1))
    tidx = targets - env.model_axis_index() * vshard
    ok = (tidx >= 0) & (tidx < vshard)
    picked = jnp.take_along_axis(lf, jnp.clip(tidx, 0, vshard - 1)[..., None],
                                 axis=-1)[..., 0]
    picked = env.psum_model(jnp.where(ok, picked, 0.0))
    logz = jnp.log(denom)
    nll = -(picked - gmax - logz)
    if z_loss:
        nll = nll + z_loss * jnp.square(logz + gmax)
    return nll


# ---------------------------------------------------------------------------
# MLPs (TP-partial outputs — no psum here)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = dict(up=dense_init(ks[0], d_model, d_ff, dtype))
    if gated:
        p["gate"] = dense_init(ks[1], d_model, d_ff, dtype)
    p["down"] = dense_init(ks[2], d_ff, d_model, dtype,
                           scale=d_ff ** -0.5)
    return p


def mlp(params, x, gated: bool = True):
    """SwiGLU / GELU MLP; returns a TP-partial output (d_ff is sharded)."""
    up = x @ params["up"]
    if gated:
        h = jax.nn.silu(x @ params["gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ params["down"]
