"""Public model API: init / apply / parameter accounting."""

from __future__ import annotations


import jax

from repro.configs.base import ModelConfig
from repro.models.transformer import (forward, init_params, logits_shard,
                                      padded_vocab, param_specs,
                                      plan_sections)
from repro.parallel.collectives import NULL_ENV, AxisEnv


def build_model(cfg: ModelConfig):
    """Returns (init_fn, apply_fn) closures for the given config."""

    def init_fn(key):
        return init_params(cfg, key)

    def apply_fn(params, tokens, env: AxisEnv = NULL_ENV, **kw):
        return forward(cfg, params, tokens, env, **kw)

    return init_fn, apply_fn


def _leaf_count(specs) -> int:
    import math
    # NOTE: not jnp.prod — int32 overflow on >2.1e9-element leaves (dbrx
    # expert stacks) silently truncated counts.
    return sum(math.prod(l.shape) for l in jax.tree.leaves(specs))


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count from the shape-only init (no allocation)."""
    return _leaf_count(param_specs(cfg))


def count_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token: routed-expert weights scaled by
    top_k/num_experts (shared experts and everything else count fully)."""
    specs = param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    total = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "experts" in keys and cfg.moe is not None:
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


def count_params_analytical(cfg: ModelConfig, active_only: bool = False) -> int:
    return count_active_params(cfg) if active_only else count_params(cfg)


def model_flops(cfg: ModelConfig, tokens: int, train: bool = False,
                decode_context: int = 0) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference fwd) with N the
    active parameter count; attention score FLOPs added explicitly for
    decode against a long context (where they dominate)."""
    n = count_active_params(cfg)
    # exclude embedding tables from the 6ND convention
    n_emb = padded_vocab(cfg.vocab_size) * cfg.d_model
    n_body = n - n_emb * (1 if cfg.tie_embeddings else 2)
    mult = 6.0 if train else 2.0
    flops = mult * n_body * tokens + 2.0 * n_emb * tokens  # lm head matmul
    if decode_context:
        # per-token attention over the KV cache; sliding-window layers only
        # see min(context, window) keys
        full_ctx = win_ctx = 0
        for k in cfg.layer_kinds():
            if k.name == "LOCAL_ATTN_MLP":
                win_ctx += 1
            elif "ATTN" in k.name or "MLA" in k.name:
                full_ctx += 1
        eff = full_ctx * decode_context + win_ctx * min(
            decode_context, cfg.sliding_window or decode_context)
        flops += mult / 3 * 2 * tokens * eff * cfg.n_heads * cfg.head_dim * 2
    return flops


__all__ = ["build_model", "count_params", "count_active_params",
           "count_params_analytical", "forward", "init_params",
           "logits_shard", "model_flops", "padded_vocab", "param_specs",
           "plan_sections"]
