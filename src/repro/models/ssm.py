"""Mamba2 block (SSD, chunked scan) — used by zamba2.

TP mapping (DESIGN.md §Hardware-adaptation): the inner dimension (heads x
head_dim) is sharded over the model axis, with per-head B/C projections
(n_groups == n_heads) so every per-head quantity lives wholly on one shard.
``out_proj`` therefore produces a TP-partial output whose completing psum is
owned by the residual topology — the Ladder schedule applies to SSM layers
exactly as to attention layers.

State per head: h ∈ R^{d_state x head_dim}; A is a negative scalar per head
(Mamba2 convention), dt is softplus-activated per head per step.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.parallel.collectives import AxisEnv


def init_mamba2(key, d_model: int, ssm, dtype):
    """Full (unsharded) Mamba2 parameters.

    The input projection is stored as separate per-segment matrices
    (z | x | B | C | dt) rather than one packed matrix so each segment can
    be column-sharded over the model axis independently (depthwise conv is
    per-channel, so the segment split is mathematically exact).
    """
    d_inner = ssm.d_inner(d_model)
    n_heads = ssm.n_heads(d_model)
    n, hd, conv = ssm.d_state, ssm.head_dim, ssm.d_conv
    ks = jax.random.split(key, 7)
    return dict(
        in_z=dense_init(ks[0], d_model, d_inner, dtype),
        in_x=dense_init(ks[1], d_model, d_inner, dtype),
        in_b=dense_init(ks[2], d_model, n_heads * n, dtype),
        in_c=dense_init(ks[3], d_model, n_heads * n, dtype),
        in_dt=dense_init(ks[4], d_model, n_heads, dtype),
        conv_x=(jax.random.normal(ks[5], (conv, d_inner), jnp.float32)
                * 0.1).astype(dtype),
        conv_b=(jax.random.normal(ks[6], (conv, n_heads * n), jnp.float32)
                * 0.1).astype(dtype),
        conv_c=(jax.random.normal(ks[6], (conv, n_heads * n), jnp.float32)
                * 0.1).astype(dtype),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        dt_bias=jnp.zeros((n_heads,), jnp.float32),
        d_skip=jnp.ones((n_heads,), jnp.float32),
        norm_w=jnp.zeros((d_inner,), dtype),
        out_proj=dense_init(ks[2], d_inner, d_model, dtype,
                            scale=d_inner ** -0.5),
    )


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv over time.  xbc: (B, S, C); conv_w: (K, C).

    Returns (activated output, new conv state of the last K-1 inputs).
    """
    k = conv_w.shape[0]
    if conv_state is not None:
        xbc_ext = jnp.concatenate([conv_state, xbc], axis=1)
        new_state = xbc_ext[:, -(k - 1):]
    else:
        xbc_ext = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = xbc_ext[:, -(k - 1):]
    out = sum(xbc_ext[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    return jax.nn.silu(out), new_state


def _ssd_chunked(x, b_mat, c_mat, dt, a_log, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B, S, H, hd); b_mat/c_mat: (B, S, H, N); dt: (B, S, H) (softplus'd)
    h0: (B, H, N, hd) initial state.  Returns (y, h_last).
    """
    bsz, s, h, hd = x.shape
    n = b_mat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    a = -jnp.exp(a_log)                                        # (H,) negative
    la = dt * a[None, None, :]                                 # log decay/step
    xs = x * dt[..., None]                                     # dt-weighted in

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, bc, cc, lac = map(to_chunks, (xs, b_mat, c_mat, la))

    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, hd), jnp.float32)

    idx = jnp.arange(chunk)
    lmask = idx[:, None] >= idx[None, :]                       # (L, L) s<=t

    def chunk_step(hprev, inp):
        xk, bk, ck, lak = inp                                  # (B,L,H,*)
        cs = jnp.cumsum(lak, axis=1)                           # (B,L,H)
        # intra-chunk: y[t] += sum_{s<=t} exp(cs_t - cs_s) (C_t.B_s) xs_s
        # mask the exponent BEFORE exp: the upper triangle would overflow
        # (cs_t - cs_s > 0 for t < s) and inf * 0 poisons the output.
        diff = cs[:, :, None] - cs[:, None, :]                 # (B,L,L,H)
        diff = jnp.where(lmask[None, :, :, None], diff, -jnp.inf)
        ratio = jnp.exp(diff)
        scores = jnp.einsum("blhn,bmhn->blmh", ck, bk,
                            preferred_element_type=jnp.float32)
        w = scores * ratio
        y = jnp.einsum("blmh,bmhd->blhd", w, xk.astype(jnp.float32))
        # inter-chunk: y[t] += exp(cs_t) C_t . h_prev
        y = y + jnp.einsum("blhn,bhnd->blhd", ck * jnp.exp(cs)[..., None],
                           hprev)
        # state update: h = exp(cs_L) h_prev + sum_s exp(cs_L - cs_s) B_s xs_s
        tot = cs[:, -1]                                        # (B,H)
        hnew = hprev * jnp.exp(tot)[..., None, None] + jnp.einsum(
            "bmhn,bmhd->bhnd", bk * jnp.exp(tot[:, None] - cs)[..., None],
            xk.astype(jnp.float32))
        return hnew, y

    h_last, ys = jax.lax.scan(chunk_step, h0, (xc, bc, cc, lac))
    y = ys.swapaxes(0, 1).reshape(bsz, nc * chunk, h, hd)[:, :s]
    return y.astype(x.dtype), h_last


def _ssd_step(x, b_vec, c_vec, dt, a_log, h):
    """Single decode step.  x: (B,1,H,hd); returns (y, h_new)."""
    a = -jnp.exp(a_log)
    la = dt[:, 0] * a[None, :]                                 # (B,H)
    decay = jnp.exp(la)[..., None, None]
    xs = (x * dt[..., None])[:, 0].astype(jnp.float32)         # (B,H,hd)
    h_new = h * decay + jnp.einsum("bhn,bhd->bhnd", b_vec[:, 0], xs)
    y = jnp.einsum("bhn,bhnd->bhd", c_vec[:, 0], h_new)
    return y[:, None].astype(x.dtype), h_new


def _grouped_rmsnorm(y, weight, z, head_dim: int, eps=1e-5):
    """Mamba2 gated norm: RMSNorm(y * silu(z)) computed PER HEAD.

    Per-head statistics keep the norm shard-local under TP (heads are never
    split across shards), so TP output is bit-identical to single-device."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    shape = yf.shape
    yh = yf.reshape(*shape[:-1], shape[-1] // head_dim, head_dim)
    var = jnp.mean(yh * yh, axis=-1, keepdims=True)
    yh = yh * jax.lax.rsqrt(var + eps)
    out = yh.reshape(shape) * (1.0 + weight.astype(jnp.float32))
    return out.astype(y.dtype)


def mamba2(params, x, env: AxisEnv, *, ssm,
           state: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Mamba2 mixer.  Returns (partial_out, new_state).

    Inside shard_map the parameter slices define the local width; head count
    is derived from the a_log slice, so the same code runs at any TP degree.
    """
    n = ssm.d_state
    hd = ssm.head_dim
    bsz, s, _ = x.shape

    n_heads = params["a_log"].shape[0]          # local heads
    d_inner = n_heads * hd

    z = x @ params["in_z"]
    xr = x @ params["in_x"]
    br = x @ params["in_b"]
    cr = x @ params["in_c"]
    dt = x @ params["in_dt"]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    if state is not None:
        cx, cb, cc = state["conv"]
    else:
        cx = cb = cc = None
    xr, ncx = _causal_conv(xr, params["conv_x"], cx)
    br, ncb = _causal_conv(br, params["conv_b"], cb)
    cr, ncc = _causal_conv(cr, params["conv_c"], cc)
    new_conv = (ncx, ncb, ncc)

    xin = xr.reshape(bsz, s, n_heads, hd)
    b_mat = br.reshape(bsz, s, n_heads, n)
    c_mat = cr.reshape(bsz, s, n_heads, n)

    if state is not None and s == 1:
        y, h_new = _ssd_step(xin, b_mat, c_mat, dt, params["a_log"],
                             state["h"])
    else:
        h0 = state["h"] if state is not None else None
        y, h_new = _ssd_chunked(xin, b_mat, c_mat, dt, params["a_log"],
                                ssm.chunk_size, h0)

    y = y + xin * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, d_inner)
    y = _grouped_rmsnorm(y, params["norm_w"], z, hd)
    out = y @ params["out_proj"]

    new_state = None
    if state is not None:
        new_state = dict(h=h_new, conv=new_conv)
    return out, new_state
