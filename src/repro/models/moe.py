"""Mixture-of-Experts FFN with expert parallelism over the model axis.

Sharding strategy ("TP-style EP", DESIGN.md §Parallelism): after attention's
AllReduce the token activations are replicated across the model axis, so each
model shard routes the full token set but evaluates only its *local* experts
(E/tp per shard).  Each shard's contribution is the capacity-limited
combine of its experts' outputs; the completing psum over the model axis is
owned by the residual topology driver — exactly the same collective slot a
dense MLP occupies, so the Ladder-Residual overlap applies to MoE layers
unchanged.

Dispatch is GShard-style with a fixed per-expert capacity so all shapes are
static (required for lowering); dropped tokens fall back to the residual
stream.  The router runs in fp32 with an optional load-balance aux loss.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, mlp
from repro.parallel.collectives import AxisEnv


def init_moe(key, d_model: int, moe_d_ff: int, num_experts: int,
             num_shared: int, dtype, gated: bool = True):
    """Full (unsharded) MoE parameters.

    experts: stacked (E, ...) tensors — sharded over the model axis on dim 0.
    shared experts are fused into one wider MLP sharded on d_ff (plain TP).
    router: replicated (it is d_model x E, tiny).
    """
    ks = jax.random.split(key, 3)
    p = dict(router=dense_init(ks[0], d_model, num_experts, jnp.float32))
    ek = jax.random.split(ks[1], 3)
    p["experts"] = dict(
        up=jax.vmap(lambda k: dense_init(k, d_model, moe_d_ff, dtype))(
            jax.random.split(ek[0], num_experts)),
        gate=jax.vmap(lambda k: dense_init(k, d_model, moe_d_ff, dtype))(
            jax.random.split(ek[1], num_experts)),
        down=jax.vmap(lambda k: dense_init(k, moe_d_ff, d_model, dtype,
                                           scale=moe_d_ff ** -0.5))(
            jax.random.split(ek[2], num_experts)),
    )
    if not gated:
        del p["experts"]["gate"]
    if num_shared:
        p["shared"] = init_mlp(ks[2], d_model, moe_d_ff * num_shared, dtype,
                               gated=gated)
    return p


def moe_ffn(params, x, env: AxisEnv, *, top_k: int, num_experts: int,
            capacity_factor: float, gated: bool = True,
            aux_loss_weight: float = 0.0,
            train: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (partial_out, aux_loss).  partial_out needs psum over model.

    x: (B, S, D) replicated over the model axis.
    params["experts"]: this shard's (E_local, ...) expert stack.
    train: capacity-factor dropping applies only in training; inference
    (prefill/decode) uses a drop-free capacity (worst case every token
    routes to the same expert), so cached decoding matches the full
    forward exactly.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    e_local = params["experts"]["up"].shape[0]
    logits = (xt.astype(jnp.float32) @ params["router"])      # (T, E) global
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, num_experts, dtype=jnp.float32),
                axis=1), axis=0)
    aux = aux_loss_weight * num_experts * jnp.sum(me * ce)

    if train:
        capacity = max(int(capacity_factor * t * top_k / num_experts), 1)
    else:
        capacity = t  # drop-free: a token assigns to an expert at most once
    # position of each (token, k) assignment within its expert's queue
    onehot = jax.nn.one_hot(gate_idx, num_experts, dtype=jnp.int32)  # (T,k,E)
    flat = onehot.reshape(t * top_k, num_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)          # (T*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(t, top_k)
    keep = pos < capacity

    shard_lo = env.model_axis_index() * e_local
    local_idx = gate_idx - shard_lo
    mine = (local_idx >= 0) & (local_idx < e_local) & keep
    local_idx = jnp.clip(local_idx, 0, e_local - 1)

    # scatter tokens into (E_local * capacity, D) buffers.  The k slots are
    # processed one at a time so no (T, k, D) tensor is ever materialised
    # (at dbrx scale that tensor would be ~3 GB/device).
    flat_dst = local_idx * capacity + jnp.clip(pos, 0, capacity - 1)
    ec = e_local * capacity
    buf = jnp.zeros((ec, d), x.dtype)
    for kk in range(top_k):
        idx_k = jnp.where(mine[:, kk], flat_dst[:, kk], ec)  # ec == dropped
        buf = buf.at[idx_k].add(jnp.where(mine[:, kk, None], xt, 0),
                                mode="drop")
    buf = buf.reshape(e_local, capacity, d)

    # expert compute: batched over local experts
    def one_expert(w, xb):
        return mlp(w, xb[None], gated=gated)[0]
    eout = jax.vmap(one_expert)(params["experts"], buf)        # (E_l, C, D)
    eout = eout.reshape(ec, d)

    # gather back, accumulating the gate-weighted expert outputs per k slot
    out = jnp.zeros((t, d), x.dtype)
    for kk in range(top_k):
        g = jnp.take(eout, jnp.clip(flat_dst[:, kk], 0, ec - 1), axis=0)
        g = jnp.where(mine[:, kk, None], g, 0)
        out = out + g * gate_vals[:, kk, None].astype(x.dtype)

    if "shared" in params:
        out = out + mlp(params["shared"], xt[None], gated=gated)[0]

    return out.reshape(b, s, d), aux
