"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

TP mapping: wkv heads are sharded over the model axis (per-head state
S ∈ R^{head_dim x head_dim} is shard-local); the time-mix output projection
and the channel-mix down projection produce TP-partial outputs whose psum is
owned by the residual topology — so the Ladder schedule covers both
sub-blocks of an attention-free architecture (DESIGN.md §Arch-applicability).

The recurrence is evaluated with a scan over time steps (jnp path).  The
Pallas kernel (kernels/rwkv6.py) evaluates the same recurrence with the state
held in VMEM; both are validated against kernels/ref.py.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.parallel.collectives import AxisEnv


def init_rwkv6(key, d_model: int, d_ff: int, rwkv, dtype):
    hd = rwkv.head_dim
    n_heads = d_model // hd
    ks = jax.random.split(key, 10)
    return dict(
        tmix=dict(
            # token-shift interpolation weights (replicated; act on d_model)
            mu_r=jnp.full((d_model,), 0.5, dtype),
            mu_k=jnp.full((d_model,), 0.5, dtype),
            mu_v=jnp.full((d_model,), 0.5, dtype),
            mu_g=jnp.full((d_model,), 0.5, dtype),
            mu_w=jnp.full((d_model,), 0.5, dtype),
            wr=dense_init(ks[0], d_model, n_heads * hd, dtype),
            wk=dense_init(ks[1], d_model, n_heads * hd, dtype),
            wv=dense_init(ks[2], d_model, n_heads * hd, dtype),
            wg=dense_init(ks[3], d_model, n_heads * hd, dtype),
            # data-dependent decay: low-rank d_model -> lora -> heads*hd
            w1=dense_init(ks[4], d_model, rwkv.decay_lora, dtype),
            w2=dense_init(ks[5], rwkv.decay_lora, n_heads * hd, dtype,
                          scale=0.1 * rwkv.decay_lora ** -0.5),
            w_bias=jnp.full((n_heads * hd,), -6.0, jnp.float32),
            u=(jax.random.normal(ks[6], (n_heads, hd), jnp.float32) * 0.1),
            ln_w=jnp.zeros((n_heads * hd,), dtype),
            wo=dense_init(ks[7], n_heads * hd, d_model, dtype,
                          scale=(n_heads * hd) ** -0.5),
        ),
        cmix=dict(
            mu_k=jnp.full((d_model,), 0.5, dtype),
            wk_up=dense_init(ks[8], d_model, d_ff, dtype),
            wv_down=dense_init(ks[9], d_ff, d_model, dtype,
                               scale=d_ff ** -0.5),
        ),
    )


def _token_shift(x, last: Optional[jnp.ndarray]):
    """x[t-1] stream; `last` carries the final token for decode."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1) \
            if x.shape[1] > 1 else last[:, None]
    return prev


def wkv6_scan(r, k, v, w, u, s0):
    """Sequential WKV6 recurrence.

    r,k,v: (B, S, H, hd); w: (B, S, H, hd) decay in (0,1); u: (H, hd).
    s0: (B, H, hd, hd) state (key-dim first).  Returns (y, s_last).
    y_t = (S_{t-1} + (u*k_t) v_t^T)^T r_t ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp                                   # (B,H,hd)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhij,bhi->bhj", s + u[None, :, :, None] * kv, rt)
        s_new = s * wt[..., None] + kv
        return s_new, y

    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, wf))     # (S,B,H,hd)
    s_last, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(r.dtype), s_last


def time_mix(p, x, env: AxisEnv, *, head_dim: int, use_pallas: bool = False,
             state: Optional[dict] = None):
    """RWKV6 time-mix.  Returns (partial_out, new_state)."""
    bsz, s, d_model = x.shape
    last = state["shift"] if state is not None else None
    prev = _token_shift(x, last)

    def lerp(mu):
        return x + (prev - x) * mu

    r = lerp(p["mu_r"]) @ p["wr"]
    k = lerp(p["mu_k"]) @ p["wk"]
    v = lerp(p["mu_v"]) @ p["wv"]
    g = lerp(p["mu_g"]) @ p["wg"]
    wx = lerp(p["mu_w"])
    w = jnp.tanh(wx @ p["w1"]) @ p["w2"]
    # decay in (0,1): exp(-exp(bias + lora))
    w = jnp.exp(-jnp.exp(p["w_bias"] + w.astype(jnp.float32)))

    n_local = r.shape[-1] // head_dim
    hshape = (bsz, s, n_local, head_dim)
    r, k, v, w = (t.reshape(hshape) for t in (r, k, v, w))

    s0 = state["wkv"] if state is not None else \
        jnp.zeros((bsz, n_local, head_dim, head_dim), jnp.float32)

    if use_pallas and state is None:
        from repro.kernels import ops
        y, s_last = ops.rwkv6(r, k, v, w, p["u"], s0)
    else:
        y, s_last = wkv6_scan(r, k, v, w, p["u"], s0)

    y = y.reshape(bsz, s, -1)
    # group norm per head then gate
    yf = y.astype(jnp.float32).reshape(bsz, s, n_local, head_dim)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = (yf * jax.lax.rsqrt(var + 1e-5)).reshape(bsz, s, -1)
    y = (yf * (1.0 + p["ln_w"].astype(jnp.float32))).astype(x.dtype)
    y = y * jax.nn.silu(g)
    out = y @ p["wo"]

    new_state = None
    if state is not None:
        new_state = dict(wkv=s_last, shift=x[:, -1])
    return out, new_state


def channel_mix(p, x, env: AxisEnv, state: Optional[dict] = None):
    """RWKV6 channel-mix (squared-ReLU FFN).  Returns (partial_out, state)."""
    last = state["shift"] if state is not None else None
    prev = _token_shift(x, last)
    xk = x + (prev - x) * p["mu_k"]
    h = jnp.square(jax.nn.relu(xk @ p["wk_up"]))
    out = h @ p["wv_down"]
    new_state = None
    if state is not None:
        new_state = dict(shift=x[:, -1])
    return out, new_state
