"""Attention modules: GQA (full / sliding-window), MLA, enc-dec cross-attn.

All functions return TP-*partial* outputs: the projection back to d_model is
computed against this shard's heads only, and the completing AllReduce is
owned by the residual topology driver (core/residual.py) — that placement is
the Ladder-Residual mechanism.

Memory discipline: naive (S, S) score materialisation at 32k/500k sequence
lengths would blow past HBM, so the jnp paths use an online-softmax scan over
query blocks ("flash in jnp"); the Pallas kernel (kernels/flash_attention.py)
implements the same algorithm with explicit VMEM tiling for TPU.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init
from repro.parallel.collectives import AxisEnv
# kv_cache is a leaf module (no model imports): safe at module level, and
# attention() dispatches on the cache type every call
from repro.serving.kv_cache import (PagedKVCache, paged_update, paged_view)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype):
    ks = jax.random.split(key, 4)
    return dict(
        wq=dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        wk=dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        wv=dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        wo=dense_init(ks[3], n_heads * head_dim, d_model, dtype,
                      scale=(n_heads * head_dim) ** -0.5),
    )


def init_mla(key, d_model: int, n_heads: int, mla, dtype):
    """DeepSeek-V2 multi-head latent attention parameters (full shapes)."""
    ks = jax.random.split(key, 6)
    qk_head = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    return dict(
        # query path (uncompressed for V2-Lite: q_lora_rank == 0)
        wq=dense_init(ks[0], d_model, n_heads * qk_head, dtype),
        # kv compression: d_model -> kv_lora + shared rope key
        wkv_a=dense_init(ks[1], d_model, mla.kv_lora_rank + mla.qk_rope_head_dim,
                         dtype),
        # decompression: kv_lora -> per-head (nope key + value)
        wkv_b=dense_init(ks[2], mla.kv_lora_rank,
                         n_heads * (mla.qk_nope_head_dim + mla.v_head_dim),
                         dtype),
        wo=dense_init(ks[3], n_heads * mla.v_head_dim, d_model, dtype,
                      scale=(n_heads * mla.v_head_dim) ** -0.5),
    )


# ---------------------------------------------------------------------------
# core softmax-attention (online, blocked)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B,Sq,Hkv,G,hd)  k: (B,Sk,Hkv,hd) -> (B,Hkv,G,Sq,Sk)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _direct_attention(q, k, v, mask, softcap: float):
    s = _gqa_scores(q, k)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _causal_mask(sq, sk, q_offset, window: int):
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m  # (sq, sk)


def blocked_causal_attention(q, k, v, *, scale: float, window: int = 0,
                             softcap: float = 0.0, block_q: int = 512,
                             block_k: int = 1024, causal: bool = True):
    """Online-softmax attention, O(block) memory (causal or bidirectional).

    q: (B, S, Hq, hd); k, v: (B, S, Hkv, hd).  Returns (B, S, Hq, hd).
    For short sequences falls back to the direct path (exact same math).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    hdv = v.shape[-1]            # value head dim may differ (MLA)
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd) * scale

    if s <= block_q:
        if causal:
            mask = _causal_mask(s, s, 0, window)[None, None, None]
        else:
            mask = jnp.ones((1, 1, 1, s, s), bool)
        out = _direct_attention(qg, k, v, mask, softcap)
        return out.reshape(b, s, hq, hdv)

    nq = -(-s // block_q)
    pad = nq * block_q - s
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qblocks = qg.reshape(b, nq, block_q, hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    kv_len = k.shape[1]

    def q_step(carry, inp):
        qi, blk = inp  # blk: (B, bq, Hkv, G, hd)
        q_off = qi * block_q
        if window:
            # only the key range [q_off - window, q_off + block_q) matters
            k_lo = jnp.maximum(q_off - window, 0)
            span = min(window + block_q, kv_len)
            k_lo = jnp.minimum(k_lo, kv_len - span)
            ks = jax.lax.dynamic_slice_in_dim(k, k_lo, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, k_lo, span, axis=1)
            qpos = q_off + jnp.arange(block_q)[:, None]
            kpos = k_lo + jnp.arange(span)[None, :]
            mask = (kpos <= qpos) & (kpos > qpos - window)
            out = _direct_attention(blk, ks, vs, mask[None, None, None], softcap)
            return carry, out
        # full causal: stream keys in blocks with online softmax
        nk = -(-kv_len // block_k)
        m0 = jnp.full((b, hkv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, block_q), jnp.float32)
        acc0 = jnp.zeros((b, block_q, hkv, g, hdv), jnp.float32)

        def k_step(st, ki):
            m, l, acc = st
            k_off = ki * block_k
            ks = jax.lax.dynamic_slice_in_dim(k, k_off, block_k, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, k_off, block_k, axis=1)
            s_blk = _gqa_scores(blk, ks)  # (B,Hkv,G,bq,bk)
            if softcap:
                s_blk = jnp.tanh(s_blk / softcap) * softcap
            qpos = q_off + jnp.arange(block_q)[:, None]
            kpos = k_off + jnp.arange(block_k)[None, :]
            valid = ((kpos <= qpos) if causal else True) & (kpos < kv_len)
            s_blk = jnp.where(valid[None, None, None], s_blk, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vs.dtype), vs)
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        # keys beyond this q block are always masked: stop early via bound
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, acc0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l.transpose(0, 3, 1, 2)[..., None], 1e-37)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qblocks))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, hq, hdv)
    return out[:, :s]


# ---------------------------------------------------------------------------
# GQA attention module (train/prefill/decode)
# ---------------------------------------------------------------------------

def _project_qkv(params, x, head_dim):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, -1, head_dim)
    k = (x @ params["wk"]).reshape(b, s, -1, head_dim)
    v = (x @ params["wv"]).reshape(b, s, -1, head_dim)
    return q, k, v


def attention(params, x, positions, env: AxisEnv, *, head_dim: int,
              rope_theta: float, window: int = 0, softcap: float = 0.0,
              use_pallas: bool = False, cache: Optional[dict] = None,
              kv_override=None, block_tables=None, attn_tune=None):
    """Causal self-attention (or cross-attention via kv_override).

    Returns (partial_out, new_cache).  partial_out requires a psum over the
    model axis (applied by the topology driver).

    cache: None for train; a KV-cache dict for prefill (length==0) or decode.
    kv_override: (k, v, kv_mask) precomputed keys/values for cross-attention.
    block_tables: (B, max_blocks) physical block ids — required when `cache`
    is a PagedKVCache; logical reads/writes go through the table.
    attn_tune: optional static (phase, occupancy-bucket) pair that keys the
    paged-kernel launch geometry into the committed tuning table
    (kernels/autotune.py); None keeps the deterministic defaults.
    """
    scale = 1.0 / math.sqrt(head_dim)
    q, k, v = _project_qkv(params, x, head_dim)
    q = apply_rope(q, positions, rope_theta)
    if kv_override is None:
        k = apply_rope(k, positions, rope_theta)

    s = x.shape[1]
    if kv_override is not None:
        ko, vo, kv_mask = kv_override
        out = _encoder_attention(q * scale, ko, vo, kv_mask, softcap)
    elif isinstance(cache, PagedKVCache):
        # Paged path (prefill chunks, decode AND speculative verify):
        # scatter this step's K/V into the block pool, then attend against
        # the row's logical view.  One code path for every step shape is
        # what makes chunked == one-shot == prefix-hit prefills
        # bit-identical — every query attends over the same valid set with
        # the mask kv_pos <= query_pos, regardless of how the prompt was
        # chunked (DESIGN.md §Paged KV).
        #
        # use_pallas picks the read implementation: the Pallas kernel walks
        # the block table in-kernel and streams only the row's own blocks
        # (DESIGN.md §Paged-attention kernel); the default gather
        # (paged_view) materialises the full table width and stays as the
        # bit-level oracle the kernel is validated against
        # (tests/test_paged_kernel.py).
        if window:
            raise NotImplementedError("paged caches for sliding-window "
                                      "attention (ring layers)")
        if block_tables is None:
            raise ValueError("paged cache requires block_tables")
        cache = paged_update(cache, k, v, positions, block_tables)
        if use_pallas:
            from repro.kernels import ops
            # int8 pools hand the kernel their per-(token, head) scales so
            # dequantization happens on the int8 tiles in VMEM; the gather
            # oracle below dequantizes inside paged_view with the same math
            phase, occ = attn_tune if attn_tune is not None else (None, 0.0)
            out = ops.paged_attention(q, cache.k, cache.v, block_tables,
                                      positions, scale=scale,
                                      block_size=cache.block_size,
                                      softcap=softcap,
                                      phase=phase, occ=occ,
                                      k_scale=cache.k_scale,
                                      v_scale=cache.v_scale)
        else:
            out = _cached_attention(q * scale,
                                    paged_view(cache, block_tables),
                                    positions, env, softcap=softcap)
    elif cache is None or s > 1:
        # train, or prefill: attention over the fresh K/V via the blocked
        # online-softmax path (prefill additionally writes the cache; the
        # math is identical to attending against the just-filled cache).
        if cache is not None:
            from repro.serving.kv_cache import cache_update
            cache = cache_update(cache, k, v, positions, env)
        if use_pallas:
            from repro.kernels import ops
            out = ops.flash_attention(q, k, v, scale=scale, window=window,
                                      softcap=softcap)
        else:
            out = blocked_causal_attention(q, k, v, scale=scale, window=window,
                                           softcap=softcap)
    else:
        from repro.serving.kv_cache import cache_update
        cache = cache_update(cache, k, v, positions, env)
        out = _cached_attention(q * scale, cache, positions, env,
                                softcap=softcap)

    b, s = x.shape[:2]
    out = out.reshape(b, s, -1)
    return out @ params["wo"], cache


def _encoder_attention(q, k, v, kv_mask, softcap: float):
    """Bidirectional / cross attention (no causal mask)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, s, hkv, hq // hkv, hd)
    mask = kv_mask[:, None, None, None, :] if kv_mask is not None else \
        jnp.ones((1, 1, 1, 1, k.shape[1]), bool)
    out = _direct_attention(qg, k, v, mask, softcap)
    return out.reshape(b, s, hq, hd)


def _cached_attention(q, cache, positions, env: AxisEnv, *, softcap: float):
    """Attention of q against a KV cache (decode / prefill-with-cache).

    Supports seq-sharded caches (flash-decoding): when the cache carries
    ``seq_shards > 1`` each data shard holds a slice of the sequence and
    partial softmax statistics are combined with a psum over the data axis.
    """
    k, v = cache["k"], cache["v"]         # heads-major: (B, Hkv, Sk, hd)
    slot_pos = cache["slot_pos"]          # (S_slots,) or ragged (B, S_slots)
    b, s, hq, hd = q.shape
    hkv = k.shape[1]
    qg = q.reshape(b, s, hkv, hq // hkv, hd)

    sp = slot_pos if slot_pos.ndim == 2 else slot_pos[None, :]  # (B|1, Sk)
    cur = positions[:, -1][:, None]        # (B,1) current absolute position
    valid = (sp >= 0) & (sp <= cur)        # (B,Sk)
    if s > 1:  # prefill into cache: causal among the new tokens
        valid = (sp[:, None, :] >= 0) & \
                (sp[:, None, :] <= positions[:, :, None])
        mask = valid[:, None, None]
    else:
        mask = valid[:, None, None, None]   # (B,1,1,1,Sk)

    # heads-major cache: dot has batch dims (b,h), contraction d — no
    # transpose of the cache is materialised
    scores = jnp.einsum("bqhgd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, NEG_INF)

    if cache.get("seq_sharded", False) and env._dp_axes():
        # flash-decoding combine: partial softmax stats across seq shards
        m_loc = jnp.max(scores, axis=-1)
        m_glob = jnp.max(env.all_gather_dp(m_loc), axis=0)
        p = jnp.exp(scores - m_glob[..., None])
        num = jnp.einsum("bhgqk,bhkd->bqhgd", p.astype(v.dtype), v)
        den = jnp.sum(p, axis=-1)
        num = env.psum_dp(num)
        den = env.psum_dp(den)
        out = num / jnp.maximum(den.transpose(0, 3, 1, 2)[..., None], 1e-37)
    else:
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(b, s, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed-KV attention
# ---------------------------------------------------------------------------

def mla_attention(params, x, positions, env: AxisEnv, *, mla, rope_theta: float,
                  cache: Optional[dict] = None):
    """Multi-head latent attention.  The KV cache stores only the compressed
    latent (kv_lora_rank) + the shared rope key — the paper-exact memory win.

    This shard holds n_heads/tp heads of wq / wkv_b / wo; wkv_a is replicated
    (it is tiny and produces the shared latent).
    """
    b, s, _ = x.shape
    rope_d, nope_d, v_d = mla.qk_rope_head_dim, mla.qk_nope_head_dim, mla.v_head_dim
    qk_head = nope_d + rope_d

    q = (x @ params["wq"]).reshape(b, s, -1, qk_head)
    n_heads_local = q.shape[2]
    q_nope, q_rope = jnp.split(q, [nope_d], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)

    ckv = x @ params["wkv_a"]                       # (B,S,lora+rope)
    c_kv, k_rope = jnp.split(ckv, [mla.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0]

    scale = 1.0 / math.sqrt(qk_head)

    if cache is not None and s > 1:
        # Prefill: write the compressed cache, then decompress the *fresh*
        # latents and run blocked attention (identical math, O(block) memory).
        from repro.serving.kv_cache import mla_cache_update
        cache = mla_cache_update(cache, c_kv, k_rope, positions, env)

    if cache is not None and s == 1:
        # --- absorbed decode path ------------------------------------------
        # Fold wkv_b into the query and output projections so scores and the
        # attention-weighted combine run directly in the compressed latent
        # space: per decode step this is O(S * kv_lora) instead of
        # re-decompressing the full K/V (the standard MLA "matrix
        # absorption"; see EXPERIMENTS.md §Perf).
        from repro.serving.kv_cache import mla_cache_update
        cache = mla_cache_update(cache, c_kv, k_rope, positions, env)
        c_kv, k_rope = cache["c_kv"], cache["k_rope"]
        slot_pos = cache["slot_pos"]

        w_b = params["wkv_b"].reshape(mla.kv_lora_rank, n_heads_local,
                                      nope_d + v_d)
        w_uk, w_uv = w_b[..., :nope_d], w_b[..., nope_d:]
        q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)      # latent query

        flash_model = cache["seq_sharded_model"] and env.model
        if flash_model:
            # §Perf hillclimb: latent cache is SEQ-sharded over the model
            # axis; queries (tiny in latent space) are gathered so every
            # shard scores ALL heads against its sequence slice, then the
            # softmax stats combine with a psum over 'model'.  Cuts decode
            # cache memory and reads by tp at the cost of ~B*H*lora bytes
            # of gathered queries per step.
            h_local = q_lat.shape[2]
            q_lat = jax.lax.all_gather(q_lat, env.model, axis=2, tiled=True)
            q_rope_g = jax.lax.all_gather(q_rope, env.model, axis=2,
                                          tiled=True)
            s_nope = jnp.einsum("bqhl,bkl->bhqk", q_lat, c_kv,
                                preferred_element_type=jnp.float32)
            s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope_g, k_rope,
                                preferred_element_type=jnp.float32)
            scores = (s_nope + s_rope) * scale
            cur = positions[:, -1][:, None]
            mask = ((slot_pos[None, :] >= 0) &
                    (slot_pos[None, :] <= cur))[:, None, None]
            scores = jnp.where(mask, scores, NEG_INF)
            m_loc = jnp.max(scores, axis=-1)
            m_glob = jnp.max(jax.lax.all_gather(m_loc, env.model), axis=0)
            p = jnp.exp(scores - m_glob[..., None])
            num = jnp.einsum("bhqk,bkl->bqhl", p.astype(c_kv.dtype), c_kv)
            den = jnp.sum(p, axis=-1)
            num = jax.lax.psum(num, env.model)
            den = jax.lax.psum(den, env.model)
            o_lat = num / jnp.maximum(
                den.transpose(0, 2, 1)[..., None].astype(num.dtype), 1e-37)
            # slice back this shard's heads for the (head-sharded) w_uv/wo
            i = env.model_axis_index()
            o_lat = jax.lax.dynamic_slice_in_dim(o_lat, i * h_local,
                                                 h_local, axis=2)
            out = jnp.einsum("bqhl,lhv->bqhv", o_lat, w_uv)
            out = out.reshape(b, s, -1)
            return out @ params["wo"], cache

        s_nope = jnp.einsum("bqhl,bkl->bhqk", q_lat, c_kv,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                            preferred_element_type=jnp.float32)
        scores = (s_nope + s_rope) * scale
        # this branch only runs at s == 1 (absorbed decode)
        sp = slot_pos if slot_pos.ndim == 2 else slot_pos[None, :]
        cur = positions[:, -1][:, None]
        mask = ((sp >= 0) & (sp <= cur))[:, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhqk,bkl->bqhl", p.astype(c_kv.dtype), c_kv)
        out = jnp.einsum("bqhl,lhv->bqhv", o_lat, w_uv)
        out = out.reshape(b, s, -1)
        return out @ params["wo"], cache

    # --- train / prefill: decompress K/V and run blocked causal attention --
    # q.k = q_nope.k_nope + q_rope.k_rope, so concatenating the rope part
    # onto both queries and keys reproduces the MLA score exactly while
    # reusing the O(block)-memory online-softmax path.
    kv = (c_kv @ params["wkv_b"]).reshape(b, c_kv.shape[1], n_heads_local,
                                          nope_d + v_d)
    k_nope, v = jnp.split(kv, [nope_d], axis=-1)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (*k_nope.shape[:3], rope_d))
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = blocked_causal_attention(q_cat, k_cat, v, scale=scale)
    out = out.reshape(b, s, -1)
    return out @ params["wo"], cache
