"""Model assembler: builds every architecture family out of sub-blocks and
runs them through the residual-topology driver (core/residual.py).

The stack is planned into *sections*: contiguous layer ranges with a
repeating kind pattern.  Each section scans over stacked per-group params
(compile-time win: one group body compiled per section regardless of depth).
Zamba2's shared attention block is planned as *virtual layers* injected every
``shared_attn_every`` Mamba layers; its parameters live outside the scanned
stack and are closed over (they are scan loop invariants).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockKind, ModelConfig, ResidualMode
from repro.core import residual as topo
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed_init, init_mlp, init_rmsnorm,
                                 lm_head_logits, mlp, rmsnorm,
                                 rmsnorm_dequant, embed_lookup)
from repro.parallel.collectives import AxisEnv

VOCAB_ALIGN = 2048  # pad vocab so every TP degree up to 16 divides evenly


def padded_vocab(vocab_size: int) -> int:
    return -(-vocab_size // VOCAB_ALIGN) * VOCAB_ALIGN


# ---------------------------------------------------------------------------
# kind -> sub-blocks
# ---------------------------------------------------------------------------

_SUBS = {
    BlockKind.ATTN_MLP: ("attn", "mlp"),
    BlockKind.LOCAL_ATTN_MLP: ("local_attn", "mlp"),
    BlockKind.ATTN_MOE: ("attn", "moe"),
    BlockKind.MLA_MOE: ("mla", "moe"),
    BlockKind.MLA_MLP: ("mla", "dense_mlp"),
    BlockKind.MAMBA2: ("mamba",),
    BlockKind.SHARED_ATTN_MLP: ("shared_attn", "shared_mlp"),
    BlockKind.RWKV6: ("rwkv_tmix", "rwkv_cmix"),
    BlockKind.CROSS_ATTN: ("attn", "xattn", "mlp"),
    "ENC_ATTN_MLP": ("enc_attn", "mlp"),
}


def subblocks_of(kind) -> Tuple[str, ...]:
    return _SUBS[kind]


def effective_kinds(cfg: ModelConfig) -> Tuple[Any, ...]:
    """Layer kinds with zamba-style shared virtual layers injected."""
    kinds: List[Any] = []
    for i in range(cfg.n_layers):
        kinds.append(cfg.block_kind(i))
        if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
            kinds.append(BlockKind.SHARED_ATTN_MLP)
    return tuple(kinds)


# ---------------------------------------------------------------------------
# section planning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SectionPlan:
    kinds: Tuple[Any, ...]        # layer kinds of ONE group
    n_groups: int
    mode: ResidualMode
    sub_idx0: int                 # global sub-block index at section start
    layer_idx0: int               # global (effective) layer index at start


def _lcm(a, b):
    return a * b // math.gcd(a, b)


def plan_sections(cfg: ModelConfig, kinds: Optional[Tuple] = None,
                  mode_of=None) -> List[SectionPlan]:
    kinds = kinds if kinds is not None else effective_kinds(cfg)
    n = len(kinds)
    desync_n = topo.desync_period(cfg.residual_mode)

    if mode_of is None:
        def mode_of(layer_idx):
            if cfg.residual_mode == ResidualMode.LADDER and \
                    layer_idx < cfg.ladder_start_layer:
                return ResidualMode.STANDARD
            return cfg.residual_mode

    # split into contiguous regions of equal mode
    regions: List[Tuple[int, int]] = []
    start = 0
    for i in range(1, n + 1):
        if i == n or mode_of(i) != mode_of(start):
            regions.append((start, i))
            start = i

    plans: List[SectionPlan] = []
    sub_idx = 0
    for lo, hi in regions:
        mode = mode_of(lo)
        i = lo
        while i < hi:
            # smallest repeating period from position i
            period = 1
            while period <= hi - i:
                if all(kinds[i + j] == kinds[i + (j % period)]
                       for j in range(hi - i)):
                    break
                period += 1
            else:
                period = hi - i
            # desync: group must cover whole periods of the AllReduce pattern
            group = list(kinds[i:i + period])
            subs = sum(len(subblocks_of(k)) for k in group)
            while desync_n > 1 and mode in (ResidualMode.DESYNC2,
                                            ResidualMode.DESYNC4) and \
                    subs % desync_n != 0 and i + len(group) + period <= hi:
                group += list(kinds[i + len(group):i + len(group) + period])
                subs = sum(len(subblocks_of(k)) for k in group)
            g = len(group)
            n_groups = (hi - i) // g
            if n_groups == 0:
                group = list(kinds[i:hi])
                g, n_groups = len(group), 1
            plans.append(SectionPlan(tuple(group), n_groups, mode, sub_idx,
                                     i))
            sub_idx += n_groups * sum(len(subblocks_of(k)) for k in group)
            i += n_groups * g
    return plans


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _init_subblock(key, cfg: ModelConfig, sub: str, dtype):
    d = cfg.d_model
    p: Dict[str, Any] = {"norm": init_rmsnorm(d, dtype)}
    if sub in ("attn", "local_attn", "enc_attn", "shared_attn"):
        p.update(attn_mod.init_attention(key, d, cfg.n_heads, cfg.n_kv_heads,
                                         cfg.head_dim, dtype))
    elif sub == "xattn":
        p.update(attn_mod.init_attention(key, d, cfg.n_heads, cfg.n_kv_heads,
                                         cfg.head_dim, dtype))
    elif sub == "mla":
        p.update(attn_mod.init_mla(key, d, cfg.n_heads, cfg.mla, dtype))
    elif sub in ("mlp", "shared_mlp"):
        p.update(init_mlp(key, d, cfg.d_ff, dtype, gated=cfg.gated_mlp))
    elif sub == "dense_mlp":
        p.update(init_mlp(key, d, cfg.dense_d_ff or cfg.d_ff, dtype,
                          gated=cfg.gated_mlp))
    elif sub == "moe":
        m = cfg.moe
        p.update(moe_mod.init_moe(key, d, m.moe_d_ff or cfg.d_ff,
                                  m.num_experts, m.num_shared_experts, dtype,
                                  gated=cfg.gated_mlp))
    elif sub == "mamba":
        p.update(ssm_mod.init_mamba2(key, d, cfg.ssm, dtype))
    elif sub in ("rwkv_tmix", "rwkv_cmix"):
        full = rwkv_mod.init_rwkv6(key, d, cfg.d_ff, cfg.rwkv, dtype)
        p.update(full["tmix"] if sub == "rwkv_tmix" else full["cmix"])
    else:
        raise ValueError(sub)
    return p


def _init_section(key, cfg: ModelConfig, plan: SectionPlan, dtype):
    """Params for one section: dict sub{j} -> stacked (n_groups, ...).

    Keys are derived from the ABSOLUTE (effective-layer, sub) position, so
    initialisation is independent of how the planner groups layers — the
    same seed yields identical weights for standard/ladder/desync/hybrid
    plans (which is what makes §4.2 conversion a pure rewiring)."""
    sec: Dict[str, Any] = {}
    j = 0
    for li, kind in enumerate(plan.kinds):
        for si, sub in enumerate(subblocks_of(kind)):
            slot = f"sub{j}"
            if sub in ("shared_attn", "shared_mlp"):
                sec[slot] = {}          # params live in params["shared_block"]
            else:
                keys = jnp.stack([
                    jax.random.fold_in(
                        jax.random.fold_in(
                            key, plan.layer_idx0 + g * len(plan.kinds) + li),
                        si)
                    for g in range(plan.n_groups)])
                sec[slot] = jax.vmap(
                    lambda k: _init_subblock(k, cfg, sub, dtype))(keys)
            j += 1
    return sec


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg.vocab_size)
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], vp, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[1], vp, cfg.d_model, dtype)

    plans = plan_sections(cfg)
    # NOTE: every section gets the SAME base key — _init_section folds in
    # absolute layer indices, so weights are plan-layout independent.
    params["sections"] = tuple(
        _init_section(ks[2], cfg, plan, dtype) for plan in plans)

    if cfg.shared_attn_every:
        params["shared_block"] = dict(
            attn=_init_subblock(ks[3], cfg, "attn", dtype),
            mlp=_init_subblock(ks[4], cfg, "mlp", dtype),
        )

    if cfg.encoder_layers:
        enc_kinds = tuple(["ENC_ATTN_MLP"] * cfg.encoder_layers)
        enc_plans = plan_sections(cfg, kinds=enc_kinds,
                                  mode_of=lambda i: cfg.residual_mode)
        params["encoder"] = dict(
            sections=tuple(
                _init_section(ks[5], cfg, plan, dtype)
                for plan in enc_plans),
            final_norm=init_rmsnorm(cfg.d_model, dtype),
        )
    return params


def param_specs(cfg: ModelConfig):
    """Shape/dtype pytree of the full parameters — no allocation."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

@dataclass
class FwdCtx:
    cfg: ModelConfig
    env: AxisEnv
    positions: jnp.ndarray
    train: bool = False
    enc_out: Optional[jnp.ndarray] = None
    enc_mask: Optional[jnp.ndarray] = None
    block_tables: Optional[jnp.ndarray] = None   # paged serving (B, blocks)
    attn_tune: Optional[tuple] = None   # (phase, occ bucket) tuning-table key


def _make_subblock_fn(ctx: FwdCtx, sub: str, slot: str, shared_params=None):
    cfg, env = ctx.cfg, ctx.env
    eps = cfg.norm_eps
    pallas = cfg.use_pallas

    def norm_in(p, x):
        if isinstance(x, topo.FusedNormInput):
            # fuse_norm ladder input: the pending AllReduce is still int8
            # images — dequant-accumulate them inside the norm pass
            # (models/layers.rmsnorm_dequant; Pallas kernel when enabled)
            return rmsnorm_dequant(x.base, x.pending.images,
                                   x.pending.scales, p["norm"], eps,
                                   use_pallas=pallas)
        return rmsnorm(x, p["norm"], eps, use_pallas=pallas)

    if sub in ("attn", "local_attn", "enc_attn", "shared_attn"):
        window = cfg.sliding_window if sub == "local_attn" else 0

        def fn(params, x, state):
            p = shared_params["attn"] if sub == "shared_attn" else params[slot]
            h = env.sp_gather(norm_in(p, x))
            if sub == "enc_attn":
                k = (h @ p["wk"]).reshape(*h.shape[:2], -1, cfg.head_dim)
                v = (h @ p["wv"]).reshape(*h.shape[:2], -1, cfg.head_dim)
                q = (h @ p["wq"]).reshape(*h.shape[:2], -1, cfg.head_dim)
                out = attn_mod.blocked_causal_attention(
                    q, k, v, scale=cfg.head_dim ** -0.5,
                    softcap=cfg.attn_logit_softcap, causal=False)
                out = out.reshape(*h.shape[:2], -1) @ p["wo"]
                return out, state, jnp.zeros((), jnp.float32)
            out, new_cache = attn_mod.attention(
                p, h, ctx.positions, env, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, window=window,
                softcap=cfg.attn_logit_softcap, use_pallas=pallas,
                cache=state, block_tables=ctx.block_tables,
                attn_tune=ctx.attn_tune)
            return out, new_cache, jnp.zeros((), jnp.float32)
        return fn

    if sub == "xattn":
        def fn(params, x, state):
            from repro.serving.kv_cache import KVCache
            p = params[slot]
            h = env.sp_gather(norm_in(p, x))
            if isinstance(state, KVCache):
                # decode: cross K/V cached heads-major at prefill; encoder
                # positions always precede decoder positions so the cached
                # path's slot_pos<=cur mask admits the full encoder context
                q = (h @ p["wq"]).reshape(*h.shape[:2], -1, cfg.head_dim)
                # match the prefill path: rope on q only (keys are encoder
                # states, cached un-roped)
                from repro.models.layers import apply_rope
                q = apply_rope(q, ctx.positions, cfg.rope_theta)
                out = attn_mod._cached_attention(
                    q * cfg.head_dim ** -0.5, state, ctx.positions, env,
                    softcap=cfg.attn_logit_softcap)
                out = out.reshape(*h.shape[:2], -1) @ p["wo"]
                return out, state, jnp.zeros((), jnp.float32)
            k = (ctx.enc_out @ p["wk"]).reshape(
                *ctx.enc_out.shape[:2], -1, cfg.head_dim)
            v = (ctx.enc_out @ p["wv"]).reshape(
                *ctx.enc_out.shape[:2], -1, cfg.head_dim)
            if state is not None:  # prefill: fill the cross cache
                # slot_pos=0 everywhere: encoder context is visible from
                # every decoder position (0 <= cur always holds)
                state = KVCache(k=k.swapaxes(1, 2), v=v.swapaxes(1, 2),
                                slot_pos=jnp.zeros((k.shape[1],), jnp.int32),
                                ring=False, seq_sharded=False)
            out, _ = attn_mod.attention(
                p, h, ctx.positions, env, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, kv_override=(k, v, ctx.enc_mask))
            return out, state, jnp.zeros((), jnp.float32)
        return fn

    if sub == "mla":
        def fn(params, x, state):
            p = params[slot]
            h = env.sp_gather(norm_in(p, x))
            out, new_cache = attn_mod.mla_attention(
                p, h, ctx.positions, env, mla=cfg.mla,
                rope_theta=cfg.rope_theta, cache=state)
            return out, new_cache, jnp.zeros((), jnp.float32)
        return fn

    if sub in ("mlp", "dense_mlp", "shared_mlp"):
        def fn(params, x, state):
            p = shared_params["mlp"] if sub == "shared_mlp" else params[slot]
            h = env.sp_gather(norm_in(p, x))
            return mlp(p, h, gated=cfg.gated_mlp), state, \
                jnp.zeros((), jnp.float32)
        return fn

    if sub == "moe":
        m = cfg.moe

        def fn(params, x, state):
            p = params[slot]
            h = env.sp_gather(norm_in(p, x))
            out, aux = moe_mod.moe_ffn(
                p, h, env, top_k=m.top_k, num_experts=m.num_experts,
                capacity_factor=m.capacity_factor, gated=cfg.gated_mlp,
                aux_loss_weight=m.aux_loss_weight if ctx.train else 0.0,
                train=ctx.train)
            return out, state, aux
        return fn

    if sub == "mamba":
        def fn(params, x, state):
            p = params[slot]
            h = env.sp_gather(norm_in(p, x))
            out, new_state = ssm_mod.mamba2(p, h, env, ssm=cfg.ssm,
                                            state=state)
            return out, new_state, jnp.zeros((), jnp.float32)
        return fn

    if sub == "rwkv_tmix":
        def fn(params, x, state):
            p = params[slot]
            h = env.sp_gather(norm_in(p, x))
            out, new_state = rwkv_mod.time_mix(
                p, h, env, head_dim=cfg.rwkv.head_dim, use_pallas=pallas,
                state=state)
            return out, new_state, jnp.zeros((), jnp.float32)
        return fn

    if sub == "rwkv_cmix":
        def fn(params, x, state):
            p = params[slot]
            h = env.sp_gather(norm_in(p, x))
            out, new_state = rwkv_mod.channel_mix(p, h, env, state=state)
            return out, new_state, jnp.zeros((), jnp.float32)
        return fn

    raise ValueError(sub)


def _section_fns(ctx: FwdCtx, plan: SectionPlan, shared_params):
    """Build (possibly parallel-fused) sub-block fns for one group."""
    fns = []
    j = 0
    for kind in plan.kinds:
        subs = subblocks_of(kind)
        kind_fns = [
            _make_subblock_fn(ctx, sub, f"sub{j + i}", shared_params)
            for i, sub in enumerate(subs)]
        j += len(subs)
        if plan.mode == ResidualMode.PARALLEL and len(kind_fns) >= 2:
            fused = kind_fns[0]
            for g in kind_fns[1:]:
                fused = topo.fuse_parallel(fused, g)
            fns.append(fused)
        else:
            fns.extend(kind_fns)
    return fns


def _parallel_pack_states(plan: SectionPlan, states):
    """PARALLEL mode fuses sub-block states into nested pairs to match
    fuse_parallel's (st1, st2) convention."""
    if states is None:
        return None
    packed = []
    j = 0
    for kind in plan.kinds:
        k = len(subblocks_of(kind))
        if k >= 2:
            cur = states[j]
            for i in range(1, k):
                cur = (cur, states[j + i])
            packed.append(cur)
        else:
            packed.append(states[j])
        j += k
    return tuple(packed)


def _parallel_unpack_states(plan: SectionPlan, packed):
    if packed is None:
        return None
    flat = []
    for kind, st in zip(plan.kinds, packed):
        k = len(subblocks_of(kind))
        if k >= 2:
            stack = []
            cur = st
            for _ in range(k - 1):
                cur, s = cur
                stack.append(s)
            stack.append(cur)
            flat.extend(reversed(stack))
        else:
            flat.append(st)
    return tuple(flat)


def run_stack(ctx: FwdCtx, sections_params, x, *, caches=None,
              plans=None, shared_params=None, section_gathers=None,
              unroll: bool = False):
    """Run all sections; returns (hidden, new_caches, aux).

    unroll: python-loop the groups instead of scanning — used for decode
    steps, where scanning would double-buffer the full KV cache through the
    loop's xs/ys while the per-layer compute is tiny (production decode
    graphs are unrolled for the same reason).
    """
    cfg, env = ctx.cfg, ctx.env
    plans = plans if plans is not None else plan_sections(cfg)
    remat = cfg.remat if ctx.train else "none"

    mode0 = plans[0].mode
    carry = topo.init_carry(mode0, x, env)
    new_caches = []
    prev_mode = mode0
    for sec_i, (plan, sec_params) in enumerate(zip(plans, sections_params)):
        if plan.mode != prev_mode:
            # topology change (hybrid adaptation): flush pendings, restart
            r, aux = topo.finalize_carry(prev_mode, carry, env)
            carry = topo.init_carry(plan.mode, r, env)
            carry.aux = carry.aux + aux
            prev_mode = plan.mode
        fns = _section_fns(ctx, plan, shared_params)
        sec_caches = caches.pop(0) if caches is not None else None
        if plan.mode == ResidualMode.PARALLEL and sec_caches is not None:
            sec_caches = _parallel_pack_states(plan, sec_caches)
        carry, ns = topo.run_section(
            plan.mode, fns, sec_params, carry, env, states=sec_caches,
            sub_idx0=plan.sub_idx0, remat=remat,
            use_scan=(plan.n_groups > 1 and not unroll),
            gather=(section_gathers[sec_i] if section_gathers else None))
        if plan.mode == ResidualMode.PARALLEL and ns is not None:
            ns = _parallel_unpack_states(plan, ns)
        new_caches.append(ns)
        prev_mode = plan.mode
    r, aux = topo.finalize_carry(prev_mode, carry, env)
    return r, (new_caches if caches is not None else None), aux


# ---------------------------------------------------------------------------
# top-level model functions
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, tokens, env: AxisEnv,
                 frontend_embeds=None):
    """Token embedding (+ prepended frontend embeddings for VLM/audio)."""
    x = embed_lookup(params["embed"], tokens, env)
    if cfg.family == "vlm" and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return x


def encode(cfg: ModelConfig, params, frames, env: AxisEnv, train=False):
    """Whisper-style encoder over precomputed frame embeddings."""
    enc_kinds = tuple(["ENC_ATTN_MLP"] * cfg.encoder_layers)
    plans = plan_sections(cfg, kinds=enc_kinds,
                          mode_of=lambda i: cfg.residual_mode)
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = FwdCtx(cfg=cfg, env=env, positions=positions, train=train)
    x = frames.astype(jnp.dtype(cfg.dtype))
    hidden, _, aux = run_stack(ctx, params["encoder"]["sections"], x,
                               plans=plans)
    hidden = rmsnorm(hidden, params["encoder"]["final_norm"], cfg.norm_eps)
    return hidden, aux


def forward(cfg: ModelConfig, params, tokens, env: AxisEnv, *,
            positions=None, caches=None, frontend_embeds=None,
            train: bool = False, section_gathers=None,
            unroll: bool = False, block_tables=None, attn_tune=None):
    """Decoder forward.  Returns (hidden, new_caches, aux_loss).

    caches: list per section of per-group-stacked state pytrees (or None).
    block_tables: (B, max_blocks) physical block ids when `caches` holds
    PagedKVCache pools (paged serving).
    attn_tune: optional static (phase, occupancy bucket) key into the
    paged-kernel tuning table (kernels/autotune.py).
    """
    enc_out = enc_mask = None
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.encoder_layers and frontend_embeds is not None:
        # encoder runs at train/prefill; decode reuses cached cross-K/V
        enc_out, aux0 = encode(cfg, params, frontend_embeds, env, train)

    x = embed_inputs(cfg, params, tokens, env, frontend_embeds
                     if cfg.family == "vlm" else None)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if env.sp and env.model and s > 1:
        # sequence parallelism: residual stream lives seq-sharded
        tp, ti = env.tp, env.model_axis_index()
        x = jax.lax.dynamic_slice_in_dim(x, ti * (s // tp), s // tp, axis=1)

    ctx = FwdCtx(cfg=cfg, env=env, positions=positions, train=train,
                 enc_out=enc_out, enc_mask=enc_mask,
                 block_tables=block_tables, attn_tune=attn_tune)
    hidden, new_caches, aux = run_stack(
        ctx, params["sections"], x,
        caches=list(caches) if caches is not None else None,
        shared_params=params.get("shared_block"),
        section_gathers=section_gathers, unroll=unroll)

    if env.sp and env.model and s > 1:
        hidden = jax.lax.all_gather(hidden, env.model, axis=1, tiled=True)

    hidden = rmsnorm(hidden, params["final_norm"], cfg.norm_eps,
                     use_pallas=cfg.use_pallas)
    return hidden, new_caches, aux + aux0


def logits_shard(cfg: ModelConfig, params, hidden):
    table = params["embed"] if cfg.tie_embeddings else \
        params.get("lm_head", params["embed"])
    return lm_head_logits(hidden, table)
