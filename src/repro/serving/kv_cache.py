"""KV caches and recurrent states for serving.

Cache classes are registered dataclass pytrees whose *meta* fields (ring,
seq_sharded) are static — they survive scan/jit boundaries while the array
fields are traced.  Uniform-length batches are assumed (all sequences in a
batch share positions), matching the paper's benchmark setup; ragged batching
is an engine-level concern (DESIGN.md §Serving).

Cache kinds
-----------
* KVCache        full attention; optionally a ring buffer (sliding window —
                 gemma3 local layers) and/or sequence-sharded over the data
                 axis (flash-decoding for long_500k, where batch=1 cannot
                 use the data axis for DP).
* MLACache       DeepSeek MLA: stores only the compressed latent + shared
                 rope key (kv_lora_rank + rope_dim per token).
* Mamba / RWKV   plain dicts of recurrent state (O(1) per layer).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.collectives import AxisEnv


@partial(jax.tree_util.register_dataclass,
         data_fields=["k", "v", "slot_pos"],
         meta_fields=["ring", "seq_sharded"])
@dataclass
class KVCache:
    # Layout (B, Hkv_local, S_slots, hd): heads-major so the decode
    # attention dot consumes the cache WITHOUT a transpose copy (at 32k
    # context a transpose would copy the full cache every decode step).
    k: jnp.ndarray            # (B, Hkv_local, S_slots, hd)
    v: jnp.ndarray
    slot_pos: jnp.ndarray     # (S_slots,) absolute position in slot, -1 empty
    ring: bool = False
    seq_sharded: bool = False

    def get(self, name, default=None):  # duck-type the old dict interface
        if name == "seq_sharded":
            return self.seq_sharded
        return default

    def __getitem__(self, name):
        return getattr(self, name)


@partial(jax.tree_util.register_dataclass,
         data_fields=["c_kv", "k_rope", "slot_pos"],
         meta_fields=["seq_sharded_model"])
@dataclass
class MLACache:
    c_kv: jnp.ndarray         # (B, S_slots, kv_lora_rank)
    k_rope: jnp.ndarray       # (B, S_slots, rope_dim)
    slot_pos: jnp.ndarray
    # MLA flash-decode: latent cache sharded over the MODEL axis on the
    # sequence dim (heads are gathered instead — they are tiny in latent
    # space), cutting per-device cache memory and decode reads by tp.
    seq_sharded_model: bool = False

    def __getitem__(self, name):
        return getattr(self, name)


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def _alloc_default(shape, dtype, fill=0):
    return jnp.full(shape, fill, dtype) if fill else jnp.zeros(shape, dtype)


def struct_alloc(shape, dtype, fill=0):
    """Allocation-free stand-in (dry-run)."""
    return jax.ShapeDtypeStruct(shape, dtype)


def make_kv_cache(batch: int, s_max: int, hkv: int, hd: int, dtype,
                  window: int = 0, seq_shards: int = 1,
                  lead: Tuple[int, ...] = (), alloc=_alloc_default) -> KVCache:
    """`lead` prepends group-stacking dims (for scan sections).

    seq_shards only sets the seq_sharded flag — the GLOBAL array keeps all
    slots; the PartitionSpec's 'data' entry provides the division (in-step
    code sees the local slice and offsets by dp_shard_index)."""
    slots = min(window, s_max) if window else s_max
    shape = (*lead, batch, hkv, slots, hd)
    return KVCache(
        k=alloc(shape, dtype), v=alloc(shape, dtype),
        slot_pos=alloc((*lead, slots), jnp.int32, fill=-1),
        ring=bool(window) and window < s_max,
        seq_sharded=seq_shards > 1)


def make_mla_cache(batch: int, s_max: int, lora: int, rope_d: int, dtype,
                   lead: Tuple[int, ...] = (), alloc=_alloc_default,
                   seq_sharded_model: bool = False) -> MLACache:
    return MLACache(
        c_kv=alloc((*lead, batch, s_max, lora), dtype),
        k_rope=alloc((*lead, batch, s_max, rope_d), dtype),
        slot_pos=alloc((*lead, s_max), jnp.int32, fill=-1),
        seq_sharded_model=seq_sharded_model)


def make_mamba_state(batch: int, n_heads: int, d_state: int, hd: int,
                     d_conv: int, dtype, lead=(), alloc=_alloc_default):
    d_inner = n_heads * hd
    return dict(
        h=alloc((*lead, batch, n_heads, d_state, hd), jnp.float32),
        conv=(alloc((*lead, batch, d_conv - 1, d_inner), dtype),
              alloc((*lead, batch, d_conv - 1, n_heads * d_state), dtype),
              alloc((*lead, batch, d_conv - 1, n_heads * d_state), dtype)))


def make_rwkv_tmix_state(batch: int, n_heads: int, hd: int, d_model: int,
                         dtype, lead=(), alloc=_alloc_default):
    return dict(wkv=alloc((*lead, batch, n_heads, hd, hd), jnp.float32),
                shift=alloc((*lead, batch, d_model), dtype))


def make_rwkv_cmix_state(batch: int, d_model: int, dtype, lead=(),
                         alloc=_alloc_default):
    return dict(shift=alloc((*lead, batch, d_model), dtype))


# ---------------------------------------------------------------------------
# updates
# ---------------------------------------------------------------------------

def _write(buf, slots, new, drop_hi: int):
    """buf: (B, S_slots, ...); slots: (S,) int32; new: (B, S, ...)."""
    slots = jnp.where((slots >= 0) & (slots < drop_hi), slots, drop_hi)
    return buf.at[:, slots].set(new, mode="drop")


def _write_hs(buf, slots, new, drop_hi: int):
    """buf: (B, H, S_slots, hd); slots: (S,); new: (B, S, H, hd)."""
    slots = jnp.where((slots >= 0) & (slots < drop_hi), slots, drop_hi)
    return buf.at[:, :, slots].set(new.swapaxes(1, 2), mode="drop")


def cache_update(cache: KVCache, k_new, v_new, positions,
                 env: AxisEnv) -> KVCache:
    """Write new K/V at `positions` (uniform across batch).

    prefill: positions = (B, S) arange; decode: (B, 1) current position.
    Ring caches keep the last `slots` tokens; seq-sharded caches write only
    the slice owned by this data shard.
    """
    slots_total = cache.k.shape[2]
    pos = positions[0]                              # uniform batch
    s = pos.shape[0]

    if cache.ring and s > slots_total:
        # prefill longer than the window: only the last `slots_total` tokens
        # can ever be read again
        k_new = k_new[:, -slots_total:]
        v_new = v_new[:, -slots_total:]
        pos = pos[-slots_total:]
        s = slots_total

    if cache.seq_sharded and env._dp_axes():
        shard_lo = env.dp_shard_index() * slots_total
        slot = pos - shard_lo
    elif cache.ring:
        slot = pos % slots_total
    else:
        slot = pos

    k = _write_hs(cache.k, slot, k_new, slots_total)
    v = _write_hs(cache.v, slot, v_new, slots_total)
    sp = cache.slot_pos.at[jnp.where((slot >= 0) & (slot < slots_total),
                                     slot, slots_total)].set(
        pos, mode="drop")
    return KVCache(k=k, v=v, slot_pos=sp, ring=cache.ring,
                   seq_sharded=cache.seq_sharded)


def mla_cache_update(cache: MLACache, c_kv, k_rope, positions,
                     env: AxisEnv = None) -> MLACache:
    slots_total = cache.c_kv.shape[1]
    pos = positions[0]
    if cache.seq_sharded_model and env is not None and env.model:
        slot = pos - env.model_axis_index() * slots_total
    else:
        slot = pos
    ck = _write(cache.c_kv, slot, c_kv, slots_total)
    kr = _write(cache.k_rope, slot, k_rope, slots_total)
    sp = cache.slot_pos.at[jnp.where((slot >= 0) & (slot < slots_total),
                                     slot, slots_total)].set(pos, mode="drop")
    return MLACache(c_kv=ck, k_rope=kr, slot_pos=sp,
                    seq_sharded_model=cache.seq_sharded_model)
