"""KV caches and recurrent states for serving.

Cache classes are registered dataclass pytrees whose *meta* fields (ring,
seq_sharded) are static — they survive scan/jit boundaries while the array
fields are traced.

Two batching regimes share these classes, distinguished by the rank of
``slot_pos``:

* uniform  — ``slot_pos: (S_slots,)``; every sequence in the batch shares
  positions (the paper's benchmark setup).
* ragged   — ``slot_pos: (B, S_slots)``; every batch row tracks its own
  positions, so a single decode step can serve a mixed-age continuous batch
  (requests admitted at different times, different prompt lengths).  Writes
  at position -1 are dropped, which is how inactive slots and prompt
  padding are expressed (DESIGN.md §Serving).

Cache kinds
-----------
* KVCache        full attention; optionally a ring buffer (sliding window —
                 gemma3 local layers) and/or sequence-sharded over the data
                 axis (flash-decoding for long_500k, where batch=1 cannot
                 use the data axis for DP).
* MLACache       DeepSeek MLA: stores only the compressed latent + shared
                 rope key (kv_lora_rank + rope_dim per token).
* PagedKVCache   full attention over a shared physical BLOCK POOL: rows own
                 logical block tables instead of contiguous s_max regions,
                 so memory is admitted block-by-block and common prompt
                 prefixes share blocks (DESIGN.md §Paged KV).  Optionally
                 stored int8 with per-(token, head) scales — 2x+ rows per
                 pool byte, dequantized in the kernel or the gather view
                 (DESIGN.md §KV memory tiers).
* Mamba / RWKV   plain dicts of recurrent state (O(1) per layer).

The host-side allocator for the paged pool (``BlockAllocator``) and the
hash-chained prefix cache (``PrefixCache``) live here too — pure Python, no
jax, unit-testable in microseconds (tests/test_paged.py).
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.collectives import AxisEnv


@partial(jax.tree_util.register_dataclass,
         data_fields=["k", "v", "slot_pos"],
         meta_fields=["ring", "seq_sharded"])
@dataclass
class KVCache:
    # Layout (B, Hkv_local, S_slots, hd): heads-major so the decode
    # attention dot consumes the cache WITHOUT a transpose copy (at 32k
    # context a transpose would copy the full cache every decode step).
    k: jnp.ndarray            # (B, Hkv_local, S_slots, hd)
    v: jnp.ndarray
    slot_pos: jnp.ndarray     # (S_slots,) absolute position in slot, -1 empty
    ring: bool = False
    seq_sharded: bool = False

    def get(self, name, default=None):  # duck-type the old dict interface
        if name == "seq_sharded":
            return self.seq_sharded
        return default

    def __getitem__(self, name):
        return getattr(self, name)


@partial(jax.tree_util.register_dataclass,
         data_fields=["c_kv", "k_rope", "slot_pos"],
         meta_fields=["seq_sharded_model"])
@dataclass
class MLACache:
    c_kv: jnp.ndarray         # (B, S_slots, kv_lora_rank)
    k_rope: jnp.ndarray       # (B, S_slots, rope_dim)
    slot_pos: jnp.ndarray
    # MLA flash-decode: latent cache sharded over the MODEL axis on the
    # sequence dim (heads are gathered instead — they are tiny in latent
    # space), cutting per-device cache memory and decode reads by tp.
    seq_sharded_model: bool = False

    def __getitem__(self, name):
        return getattr(self, name)


@partial(jax.tree_util.register_dataclass,
         data_fields=["k", "v", "k_scale", "v_scale"],
         meta_fields=["block_size", "quant"])
@dataclass
class PagedKVCache:
    """Physical block pool shared by every request (DESIGN.md §Paged KV).

    Token slots are flat: block ``b`` owns pool positions
    ``[b*block_size, (b+1)*block_size)``.  Heads-major like ``KVCache`` so
    gathered views feed the decode dot without a transpose of the pool.
    Which rows own which blocks lives host-side (``BlockAllocator`` +
    the paged scheduler's block tables) — the device only ever sees a
    ``block_tables: (B, max_blocks)`` int32 argument per step.

    quant == "int8" stores the pool as symmetric int8 with per-(token,
    head) float32 scales alongside (DESIGN.md §KV memory tiers): pool slot
    ``t`` of head ``h`` dequantizes to ``k[h, t] * k_scale[h, t]``.  Scales
    are block-major like the token slots, so readers translate logical ->
    physical once and slice both arrays with it; quantized bytes + scale
    are a pure function of that token's K/V, never re-fitted by later
    writes — which is what keeps chunked prefill bit-equal to one-shot and
    makes swap round-trips byte-identical (quantized bytes move, never
    re-quantized).
    """
    k: jnp.ndarray            # (Hkv_local, num_blocks * block_size, hd)
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray] = None   # (Hkv_local, num_blocks * bs)
    v_scale: Optional[jnp.ndarray] = None
    block_size: int = 16
    quant: str = "fp"         # "fp" | "int8"

    def get(self, name, default=None):
        return getattr(self, name, default)

    def __getitem__(self, name):
        return getattr(self, name)


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def _alloc_default(shape, dtype, fill=0):
    return jnp.full(shape, fill, dtype) if fill else jnp.zeros(shape, dtype)


def struct_alloc(shape, dtype, fill=0):
    """Allocation-free stand-in (dry-run)."""
    return jax.ShapeDtypeStruct(shape, dtype)


def make_kv_cache(batch: int, s_max: int, hkv: int, hd: int, dtype,
                  window: int = 0, seq_shards: int = 1,
                  lead: Tuple[int, ...] = (), alloc=_alloc_default,
                  ragged: bool = False) -> KVCache:
    """`lead` prepends group-stacking dims (for scan sections).

    seq_shards only sets the seq_sharded flag — the GLOBAL array keeps all
    slots; the PartitionSpec's 'data' entry provides the division (in-step
    code sees the local slice and offsets by dp_shard_index).

    ragged: per-batch-row position tracking (slot_pos gains a batch dim);
    required by the continuous-batching engine, incompatible with
    seq_shards > 1."""
    if ragged and seq_shards > 1:
        raise NotImplementedError("ragged + seq-sharded caches")
    slots = min(window, s_max) if window else s_max
    shape = (*lead, batch, hkv, slots, hd)
    sp_shape = (*lead, batch, slots) if ragged else (*lead, slots)
    return KVCache(
        k=alloc(shape, dtype), v=alloc(shape, dtype),
        slot_pos=alloc(sp_shape, jnp.int32, fill=-1),
        ring=bool(window) and window < s_max,
        seq_sharded=seq_shards > 1)


def kv_block_bytes(block_size: int, hkv: int, hd: int, esize: int,
                   quant: str = "fp") -> int:
    """Bytes one physical block occupies under a pool storage mode: the
    k AND v planes, plus (int8) one float32 scale per (token, head) per
    plane.  The single source of truth for pool-economics math
    (benchmarks/serve_bench.py, benchmarks/kernel_bench.py,
    examples/serve_batched.py)."""
    if quant == "int8":
        return block_size * 2 * hkv * (hd + 4)
    return block_size * 2 * hkv * hd * esize


def make_paged_kv_cache(num_blocks: int, block_size: int, hkv: int, hd: int,
                        dtype, lead: Tuple[int, ...] = (),
                        alloc=_alloc_default,
                        quant: str = "fp") -> PagedKVCache:
    """Allocate a physical block pool of ``num_blocks * block_size`` token
    slots (shared across all requests; `lead` prepends scan group dims).

    quant="int8" stores the pool as int8 with per-(token, head) float32
    scales — half-or-better HBM per slot vs bf16/f32 pools, so the same
    byte budget admits ~2x the concurrent rows (DESIGN.md §KV memory
    tiers)."""
    if quant not in ("fp", "int8"):
        raise ValueError(f"unknown KV quant mode {quant!r}")
    shape = (*lead, hkv, num_blocks * block_size, hd)
    if quant == "int8":
        sshape = shape[:-1]
        return PagedKVCache(
            k=alloc(shape, jnp.int8), v=alloc(shape, jnp.int8),
            k_scale=alloc(sshape, jnp.float32),
            v_scale=alloc(sshape, jnp.float32),
            block_size=block_size, quant=quant)
    return PagedKVCache(k=alloc(shape, dtype), v=alloc(shape, dtype),
                        block_size=block_size)


def make_mla_cache(batch: int, s_max: int, lora: int, rope_d: int, dtype,
                   lead: Tuple[int, ...] = (), alloc=_alloc_default,
                   seq_sharded_model: bool = False,
                   ragged: bool = False) -> MLACache:
    """Compressed-latent MLA cache: c_kv (*lead, B, S, lora) + k_rope
    (*lead, B, S, rope_d).  ragged adds the batch dim to slot_pos
    ((*lead, B, S) instead of (*lead, S)); seq_sharded_model shards the
    SEQUENCE over the model axis (MLA flash-decode) and is uniform-only."""
    if ragged and seq_sharded_model:
        raise NotImplementedError("ragged + model-seq-sharded MLA cache")
    sp_shape = (*lead, batch, s_max) if ragged else (*lead, s_max)
    return MLACache(
        c_kv=alloc((*lead, batch, s_max, lora), dtype),
        k_rope=alloc((*lead, batch, s_max, rope_d), dtype),
        slot_pos=alloc(sp_shape, jnp.int32, fill=-1),
        seq_sharded_model=seq_sharded_model)


def make_mamba_state(batch: int, n_heads: int, d_state: int, hd: int,
                     d_conv: int, dtype, lead=(), alloc=_alloc_default):
    """O(1)-per-token recurrent state: ssm state h (*lead, B, H, N, hd) in
    f32 plus three conv shift buffers of the last d_conv-1 inputs.  Batch
    on axis 1 (after `lead`) like every cache leaf, so the slot
    slice/insert helpers apply unchanged."""
    d_inner = n_heads * hd
    return dict(
        h=alloc((*lead, batch, n_heads, d_state, hd), jnp.float32),
        conv=(alloc((*lead, batch, d_conv - 1, d_inner), dtype),
              alloc((*lead, batch, d_conv - 1, n_heads * d_state), dtype),
              alloc((*lead, batch, d_conv - 1, n_heads * d_state), dtype)))


def make_rwkv_tmix_state(batch: int, n_heads: int, hd: int, d_model: int,
                         dtype, lead=(), alloc=_alloc_default):
    """RWKV time-mix state: wkv (*lead, B, H, hd, hd) f32 + token-shift
    buffer (*lead, B, d_model)."""
    return dict(wkv=alloc((*lead, batch, n_heads, hd, hd), jnp.float32),
                shift=alloc((*lead, batch, d_model), dtype))


def make_rwkv_cmix_state(batch: int, d_model: int, dtype, lead=(),
                         alloc=_alloc_default):
    """RWKV channel-mix state: just the token-shift buffer
    (*lead, B, d_model)."""
    return dict(shift=alloc((*lead, batch, d_model), dtype))


# ---------------------------------------------------------------------------
# updates
# ---------------------------------------------------------------------------

def _write(buf, slots, new, drop_hi: int):
    """buf: (B, S_slots, ...); slots: (S,) int32; new: (B, S, ...)."""
    slots = jnp.where((slots >= 0) & (slots < drop_hi), slots, drop_hi)
    return buf.at[:, slots].set(new, mode="drop")


def _write_hs(buf, slots, new, drop_hi: int):
    """buf: (B, H, S_slots, hd); slots: (S,); new: (B, S, H, hd)."""
    slots = jnp.where((slots >= 0) & (slots < drop_hi), slots, drop_hi)
    return buf.at[:, :, slots].set(new.swapaxes(1, 2), mode="drop")


def _write_ragged(buf, slots, new, drop_hi: int):
    """Per-row scatter.  buf: (B, S_slots, ...); slots: (B, S); new (B, S, ...)."""
    def one(bufb, slotb, newb):
        s = jnp.where((slotb >= 0) & (slotb < drop_hi), slotb, drop_hi)
        return bufb.at[s].set(newb, mode="drop")
    return jax.vmap(one)(buf, slots, new)


def _write_hs_ragged(buf, slots, new, drop_hi: int):
    """Per-row scatter, heads-major.  buf: (B, H, S_slots, hd);
    slots: (B, S); new: (B, S, H, hd)."""
    def one(bufb, slotb, newb):
        s = jnp.where((slotb >= 0) & (slotb < drop_hi), slotb, drop_hi)
        return bufb.at[:, s].set(newb.swapaxes(0, 1), mode="drop")
    return jax.vmap(one)(buf, slots, new)


def _slot_pos_scatter(slot_pos, slot, pos, slots_total: int):
    """Record absolute positions at the written slots (1-D or per-row 2-D)."""
    idx = jnp.where((slot >= 0) & (slot < slots_total), slot, slots_total)
    if slot_pos.ndim == 2:
        return jax.vmap(lambda spb, ib, pb: spb.at[ib].set(pb, mode="drop"))(
            slot_pos, idx, pos)
    return slot_pos.at[idx].set(pos, mode="drop")


def cache_update(cache: KVCache, k_new, v_new, positions,
                 env: AxisEnv) -> KVCache:
    """Write new K/V at `positions`.

    prefill: positions = (B, S) arange; decode: (B, 1) current position.
    Ring caches keep the last `slots` tokens; seq-sharded caches write only
    the slice owned by this data shard.  Ragged caches (slot_pos has a batch
    dim) write per-row — positions may differ across the batch and entries
    at position -1 are dropped (inactive slots / prompt padding).
    """
    slots_total = cache.k.shape[2]

    if cache.slot_pos.ndim == 2:                    # ragged: per-row writes
        pos = positions                             # (B, S)
        if cache.ring:
            # prefill longer than the window: only each row's last
            # `slots_total` positions may land (duplicate ring slots in one
            # scatter would be order-undefined)
            row_max = jnp.max(pos, axis=1, keepdims=True)
            pos = jnp.where(pos > row_max - slots_total, pos, -1)
            slot = pos % slots_total
        else:
            slot = pos
        slot = jnp.where(pos >= 0, slot, -1)
        k = _write_hs_ragged(cache.k, slot, k_new, slots_total)
        v = _write_hs_ragged(cache.v, slot, v_new, slots_total)
        sp = _slot_pos_scatter(cache.slot_pos, slot, pos, slots_total)
        return KVCache(k=k, v=v, slot_pos=sp, ring=cache.ring,
                       seq_sharded=cache.seq_sharded)

    pos = positions[0]                              # uniform batch
    s = pos.shape[0]

    if cache.ring and s > slots_total:
        # prefill longer than the window: only the last `slots_total` tokens
        # can ever be read again
        k_new = k_new[:, -slots_total:]
        v_new = v_new[:, -slots_total:]
        pos = pos[-slots_total:]
        s = slots_total

    if cache.seq_sharded and env._dp_axes():
        shard_lo = env.dp_shard_index() * slots_total
        slot = pos - shard_lo
    elif cache.ring:
        slot = pos % slots_total
    else:
        slot = pos

    k = _write_hs(cache.k, slot, k_new, slots_total)
    v = _write_hs(cache.v, slot, v_new, slots_total)
    sp = cache.slot_pos.at[jnp.where((slot >= 0) & (slot < slots_total),
                                     slot, slots_total)].set(
        pos, mode="drop")
    return KVCache(k=k, v=v, slot_pos=sp, ring=cache.ring,
                   seq_sharded=cache.seq_sharded)


def mla_cache_update(cache: MLACache, c_kv, k_rope, positions,
                     env: AxisEnv = None) -> MLACache:
    """Write compressed latents at `positions` ((B, S); -1 drops) — the MLA
    analogue of ``cache_update``: per-row scatters when slot_pos is ragged
    (2-D), model-axis offset when the latent cache is seq-sharded."""
    slots_total = cache.c_kv.shape[1]
    if cache.slot_pos.ndim == 2:                    # ragged: per-row writes
        slot = positions                            # (B, S)
        ck = _write_ragged(cache.c_kv, slot, c_kv, slots_total)
        kr = _write_ragged(cache.k_rope, slot, k_rope, slots_total)
        sp = _slot_pos_scatter(cache.slot_pos, slot, positions, slots_total)
        return MLACache(c_kv=ck, k_rope=kr, slot_pos=sp,
                        seq_sharded_model=cache.seq_sharded_model)
    pos = positions[0]
    if cache.seq_sharded_model and env is not None and env.model:
        slot = pos - env.model_axis_index() * slots_total
    else:
        slot = pos
    ck = _write(cache.c_kv, slot, c_kv, slots_total)
    kr = _write(cache.k_rope, slot, k_rope, slots_total)
    sp = cache.slot_pos.at[jnp.where((slot >= 0) & (slot < slots_total),
                                     slot, slots_total)].set(pos, mode="drop")
    return MLACache(c_kv=ck, k_rope=kr, slot_pos=sp,
                    seq_sharded_model=cache.seq_sharded_model)


# ---------------------------------------------------------------------------
# paged pool access (DESIGN.md §Paged KV)
# ---------------------------------------------------------------------------

def paged_update(cache: PagedKVCache, k_new, v_new, positions,
                 block_tables) -> PagedKVCache:
    """Scatter new K/V into the block pool.

    positions: (B, S) logical per-row positions (-1 drops the write);
    block_tables: (B, max_blocks) physical block ids.  Logical position p of
    row b lands at pool slot ``bt[b, p // bs] * bs + p % bs``.  The host
    allocator guarantees rows never write a block with refcount > 1 (the
    copy-on-write invariant), so one flat scatter is race-free.
    """
    bs = cache.block_size
    n_tok = cache.k.shape[-2]
    pos_c = jnp.maximum(positions, 0)
    phys = jnp.take_along_axis(block_tables, pos_c // bs, axis=1)
    flat = jnp.where(positions >= 0, phys * bs + pos_c % bs, n_tok)
    flat = flat.reshape(-1)                               # (B*S,)
    kf = k_new.reshape(-1, *k_new.shape[2:]).swapaxes(0, 1)   # (Hkv,B*S,hd)
    vf = v_new.reshape(-1, *v_new.shape[2:]).swapaxes(0, 1)
    if cache.quant == "int8":
        # quantize-on-scatter: each (token, head) vector gets its own int8
        # image + scale, so a write never disturbs other tokens' bytes
        # (DESIGN.md §KV memory tiers)
        from repro.quant import quantize_kv
        kq, ks = quantize_kv(kf)
        vq, vs = quantize_kv(vf)
        return PagedKVCache(
            k=cache.k.at[:, flat].set(kq, mode="drop"),
            v=cache.v.at[:, flat].set(vq, mode="drop"),
            k_scale=cache.k_scale.at[:, flat].set(ks, mode="drop"),
            v_scale=cache.v_scale.at[:, flat].set(vs, mode="drop"),
            block_size=bs, quant=cache.quant)
    return PagedKVCache(
        k=cache.k.at[:, flat].set(kf.astype(cache.k.dtype), mode="drop"),
        v=cache.v.at[:, flat].set(vf.astype(cache.v.dtype), mode="drop"),
        block_size=bs)


def paged_view(cache: PagedKVCache, block_tables) -> KVCache:
    """Gather each row's logical K/V view from the pool.

    Returns a ragged ``KVCache`` of ``max_blocks * block_size`` slots per row
    whose slot s holds logical position s (``slot_pos[b, s] = s``): the
    ragged attention mask ``slot_pos <= cur`` then reads exactly the row's
    written prefix — unwritten/unallocated table entries sit at s > cur and
    are masked.  When ``max_blocks * block_size == s_max`` this view is
    shape- and bit-identical to the dense ragged cache read, which is what
    the paged-vs-ragged engine equivalence tests pin down.

    int8 pools dequantize in the gather (``q * scale`` per token, head) —
    this stays the bit-level oracle for the kernel's in-VMEM dequant path
    (tests/test_memory.py).
    """
    bs = cache.block_size
    b, m = block_tables.shape
    idx = (block_tables[:, :, None] * bs +
           jnp.arange(bs, dtype=block_tables.dtype)).reshape(b, m * bs)
    k = jnp.take(cache.k, idx, axis=1).swapaxes(0, 1)     # (B, Hkv, L, hd)
    v = jnp.take(cache.v, idx, axis=1).swapaxes(0, 1)
    if cache.quant == "int8":
        from repro.quant import dequantize_kv
        ks = jnp.take(cache.k_scale, idx, axis=1).swapaxes(0, 1)  # (B,Hkv,L)
        vs = jnp.take(cache.v_scale, idx, axis=1).swapaxes(0, 1)
        k = dequantize_kv(k, ks)
        v = dequantize_kv(v, vs)
    sp = jnp.broadcast_to(jnp.arange(m * bs, dtype=jnp.int32), (b, m * bs))
    return KVCache(k=k, v=v, slot_pos=sp, ring=False, seq_sharded=False)


# ---------------------------------------------------------------------------
# slot lifecycle (continuous batching; DESIGN.md §Serving)
# ---------------------------------------------------------------------------
# Ragged section caches are pytrees in which EVERY array leaf carries the
# batch on axis 1 (axis 0 is the scan group-stacking dim), so one slot's
# state can be sliced out / scattered back generically.

_CACHE_TYPES = (KVCache, MLACache)


def _is_state(x):
    return isinstance(x, _CACHE_TYPES)


def reset_slot_state(slot_caches):
    """Fresh per-request state for a just-sliced slot: KV-style caches get
    slot_pos = -1 (entries masked out; stale K/V rows are unreachable),
    recurrent states (mamba/rwkv dicts) are zeroed."""
    def reset(c):
        if isinstance(c, KVCache):
            return KVCache(k=c.k, v=c.v,
                           slot_pos=jnp.full_like(c.slot_pos, -1),
                           ring=c.ring, seq_sharded=c.seq_sharded)
        if isinstance(c, MLACache):
            return MLACache(c_kv=c.c_kv, k_rope=c.k_rope,
                            slot_pos=jnp.full_like(c.slot_pos, -1),
                            seq_sharded_model=c.seq_sharded_model)
        return jax.tree.map(jnp.zeros_like, c)
    return jax.tree.map(reset, slot_caches, is_leaf=_is_state)


def slice_slot(caches, slot):
    """Extract slot `slot` (batch axis 1) as a batch-1 view of the caches."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), caches)


def insert_slot(caches, slot_caches, slot):
    """Scatter a batch-1 slot state back into the full-batch caches."""
    return jax.tree.map(
        lambda big, small: jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis=1),
        caches, slot_caches)


# ---------------------------------------------------------------------------
# host-side block management (paged serving; DESIGN.md §Paged KV)
# ---------------------------------------------------------------------------

class BlockAllocationError(RuntimeError):
    """Raised when the pool has no free (or reclaimable) block.

    The non-preemptive scheduler's reservation accounting makes this
    unreachable mid-flight; the preemptive scheduler (serving/memory.py)
    catches it as the signal to swap out a victim row.
    """


class BlockAllocator:
    """Free-list + refcount allocator over the physical block pool.

    Pure host bookkeeping — block *contents* live on device and are never
    touched here.  Shared prefix blocks carry refcount > 1; a block may only
    be written while its refcount is exactly 1 (the scheduler asserts this —
    the copy-on-write invariant: diverge by allocating a fresh block, never
    by mutating a shared one).

    Misuse raises instead of corrupting state: refcount underflow
    (double-``decref``), freeing a live block, and double-``free`` all
    raise ``ValueError`` — an exception here means a scheduler bug, and a
    silently double-inserted free-list entry would hand the same physical
    block to two rows (cross-request K/V corruption, the worst possible
    failure mode).  Exceptions, not asserts: the guards must survive
    ``python -O``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of at least one token")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # stack: low ids allocated first (stable tests / readable tables)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._free_set = set(self._free)       # O(1) double-free detection
        self._ref: List[int] = [0] * num_blocks
        self.total_allocs = 0          # lifetime alloc() count (stats)

    def num_free(self) -> int:
        return len(self._free)

    def num_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, blk: int) -> int:
        return self._ref[blk]

    def alloc(self) -> int:
        if not self._free:
            raise BlockAllocationError("BlockAllocator: out of KV blocks")
        blk = self._free[-1]            # validate BEFORE mutating any state
        if self._ref[blk] != 0:
            raise ValueError(f"free-listed block {blk} has refcount "
                             f"{self._ref[blk]}")
        self._free.pop()
        self._free_set.discard(blk)
        self._ref[blk] = 1
        self.total_allocs += 1
        return blk

    def incref(self, blk: int) -> int:
        if blk in self._free_set:
            raise ValueError(f"incref of free-listed block {blk}")
        # refcount 0 is legal here: evictable prefix-cache residents are
        # revived by increfing 0 -> 1 (they are off the free list)
        self._ref[blk] += 1
        return self._ref[blk]

    def decref(self, blk: int) -> int:
        if self._ref[blk] <= 0:
            raise ValueError(f"refcount underflow: double decref of "
                             f"block {blk}")
        self._ref[blk] -= 1
        return self._ref[blk]

    def free(self, blk: int):
        """Return a refcount-0 block to the free list."""
        if self._ref[blk] != 0:
            raise ValueError(f"freeing live block {blk} "
                             f"(refcount {self._ref[blk]})")
        if blk in self._free_set:
            raise ValueError(f"double free of block {blk}")
        self._free.append(blk)
        self._free_set.add(blk)


class PrefixCache:
    """Hash-chained prompt prefix -> physical block map.

    A FULL block of a prompt is keyed by the hash chain
    ``h_i = hash((h_{i-1}, tokens[i*bs:(i+1)*bs]))`` so equal keys imply an
    equal whole prefix, not just an equal block.  Only blocks whose K/V is
    completely written are ever inserted (a concurrently-prefilling request
    must not hit a half-filled block).  Blocks whose refcount drops to zero
    stay cached but *evictable* (LRU): the scheduler reclaims them when the
    free list runs dry, so a retired request's system prompt keeps serving
    hits until memory pressure actually needs the blocks back.
    """

    _SEED = 0x51ED5EED

    def __init__(self):
        self._table: Dict[int, int] = {}          # chain hash -> block id
        self._by_block: Dict[int, int] = {}       # block id -> chain hash
        self._evictable: "OrderedDict[int, int]" = OrderedDict()  # blk -> h

    @classmethod
    def chain(cls, prev_hash: Optional[int], tokens) -> int:
        return hash(((cls._SEED if prev_hash is None else prev_hash),
                     tuple(tokens)))

    def lookup(self, h: int) -> Optional[int]:
        return self._table.get(h)

    def contains_block(self, blk: int) -> bool:
        return blk in self._by_block

    def insert(self, h: int, blk: int):
        """Register a fully-written block; first writer wins on hash ties."""
        if h not in self._table:
            self._table[h] = blk
            self._by_block[blk] = h

    def mark_evictable(self, blk: int):
        """Called when a registered block's refcount hits 0: keep it cached
        but reclaimable (most-recently-retired evicted last)."""
        self._evictable[blk] = self._by_block[blk]
        self._evictable.move_to_end(blk)

    def revive(self, blk: int):
        """A cached block got a hit while evictable: pin it again."""
        self._evictable.pop(blk, None)

    def num_evictable(self) -> int:
        return len(self._evictable)

    def pop_lru(self) -> int:
        """Surrender the least-recently-used evictable block (drops its
        registration — the chain simply stops matching there)."""
        blk, h = self._evictable.popitem(last=False)
        del self._table[h]
        del self._by_block[blk]
        return blk
