"""Serving engine: jitted prefill / decode / verify steps for every cache
regime.

``build_caches`` mirrors the assembler's section plan so cache pytrees line
up with the scanned parameter stacks.  Three step families share it:

* ``build_serve_steps``       — uniform-batch prefill/decode (the paper's
  benchmark shape); shard_map'ped, the multi-pod dry-run lowers exactly
  these.
* ``build_continuous_steps``  — ragged-cache steps for the
  continuous-batching engine (per-row ``slot_pos``; DESIGN.md §Serving).
* ``build_paged_steps``       — block-pool steps (``block_tables`` threaded
  through ``tfm.forward``; DESIGN.md §Paged KV) plus the speculative
  ``verify`` steps (DESIGN.md §Speculative decoding).

Each builder's docstring is the shape contract for the closures it
returns; the host-side drivers live in serving/scheduler.py and
serving/speculative.py.

Long-context decode (long_500k, global_batch=1) cannot use the data axis for
batch DP, so the KV cache is sharded over the *sequence* on the data axis and
attention decode runs flash-decoding style (partial softmax stats combined
with a psum over 'data') — see models/attention._cached_attention.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import transformer as tfm
from repro.parallel import sharding
from repro.parallel.collectives import CommConfig
from repro.parallel.tp import make_axis_env
from repro.serving import kv_cache as kvc
from repro.serving import sampler


def _batch_axes(pcfg: ParallelConfig):
    return ("pod", "data") if pcfg.pods > 1 else ("data",) if pcfg.dp > 1 else ()


def build_caches(cfg: ModelConfig, batch: int, s_max: int,
                 pcfg: ParallelConfig, *, for_decode: bool,
                 seq_shard_data: bool = False, enc_s: int = 0,
                 structs_only: bool = False, ragged: bool = False,
                 paged: bool = False, num_blocks: int = 0,
                 block_size: int = 16, kv_quant: str = "fp"):
    """Build (caches, cache_pspecs) as GLOBAL pytrees.

    seq_shard_data: shard KV sequence over the data axis (flash decoding) —
    used when the batch is too small for data parallelism (long_500k).
    enc_s: encoder context length for cross-attention caches (enc-dec).
    structs_only: produce ShapeDtypeStructs (dry-run — no allocation).
    ragged: per-batch-row position tracking (continuous batching) — every
    cache leaf carries the batch on axis 1, so slots can be sliced/reset
    independently (DESIGN.md §Serving).  Incompatible with seq_shard_data.
    paged: block-pool caches (DESIGN.md §Paged KV) — attention layers get a
    shared PagedKVCache pool of `num_blocks` x `block_size` token slots
    instead of per-slot s_max regions; `batch` is ignored for those layers
    (the block tables map rows to blocks).  Full attention only.
    kv_quant: "int8" stores the paged pool quantized with per-(token, head)
    scales (DESIGN.md §KV memory tiers); "fp" keeps the model dtype.
    """
    if ragged and seq_shard_data:
        raise NotImplementedError("ragged + seq-sharded caches")
    if ragged and cfg.encoder_layers:
        raise NotImplementedError("ragged caches for enc-dec models "
                                  "(cross-attention slots are per-utterance)")
    if paged and (ragged or seq_shard_data):
        raise NotImplementedError("paged + ragged/seq-sharded caches")
    if paged and num_blocks < 1:
        raise ValueError("paged caches need num_blocks >= 1")
    dtype = jnp.dtype(cfg.dtype)
    alloc = kvc.struct_alloc if structs_only else kvc._alloc_default
    plan = tfm.plan_sections(cfg)
    hp = sharding.tp_head_plan(cfg.n_heads, cfg.n_kv_heads, pcfg.tp)
    b_axes = _batch_axes(pcfg)
    # bax: mesh axes sharding the batch dim (None when batch is replicated,
    # e.g. batch=1 long-context where the data axis shards the sequence)
    bax = b_axes if (b_axes and not seq_shard_data) else None
    seq_shards = (pcfg.dp if seq_shard_data else 1)
    sspec = "data" if seq_shard_data and pcfg.dp > 1 else None
    tp_ax = "model" if pcfg.tp > 1 else None
    # ragged slot_pos carries (group, batch, slots) — batch sharded like k/v
    sp_spec = (lambda tail: P(None, bax, tail)) if ragged else \
        (lambda tail: P(None, tail))

    caches, specs = [], []
    for sec in plan:
        lead = (sec.n_groups,)
        sec_caches, sec_specs = [], []
        for kind in sec.kinds:
            for sub in tfm.subblocks_of(kind):
                if paged and sub not in ("attn", "mlp", "moe", "dense_mlp"):
                    raise NotImplementedError(
                        f"paged caches for sub-block {sub!r} (full "
                        "attention only; ring/MLA/recurrent state keeps "
                        "the ragged path)")
                if paged and sub == "attn":
                    c = kvc.make_paged_kv_cache(num_blocks, block_size,
                                                hp.kv_eff, cfg.head_dim,
                                                dtype, lead=lead, alloc=alloc,
                                                quant=kv_quant)
                    sc_spec = P(None, tp_ax, None) \
                        if kv_quant == "int8" else None
                    s = kvc.PagedKVCache(k=P(None, tp_ax, None, None),
                                         v=P(None, tp_ax, None, None),
                                         k_scale=sc_spec, v_scale=sc_spec,
                                         block_size=block_size,
                                         quant=kv_quant)
                elif sub in ("attn", "shared_attn"):
                    c = kvc.make_kv_cache(batch, s_max, hp.kv_eff,
                                          cfg.head_dim, dtype, alloc=alloc,
                                          seq_shards=seq_shards, lead=lead,
                                          ragged=ragged)
                    s = kvc.KVCache(k=P(None, bax, tp_ax, sspec, None),
                                    v=P(None, bax, tp_ax, sspec, None),
                                    slot_pos=sp_spec(sspec),
                                    ring=c.ring, seq_sharded=c.seq_sharded)
                elif sub == "local_attn":
                    c = kvc.make_kv_cache(batch, s_max, hp.kv_eff,
                                          cfg.head_dim, dtype, alloc=alloc,
                                          window=cfg.sliding_window, lead=lead,
                                          ragged=ragged)
                    s = kvc.KVCache(k=P(None, bax, tp_ax, None, None),
                                    v=P(None, bax, tp_ax, None, None),
                                    slot_pos=sp_spec(None),
                                    ring=c.ring, seq_sharded=False)
                elif sub == "mla":
                    ssm_flag = getattr(cfg, "mla_flash_decode", False) and \
                        pcfg.tp > 1 and not ragged
                    c = kvc.make_mla_cache(batch, s_max, cfg.mla.kv_lora_rank,
                                           cfg.mla.qk_rope_head_dim, dtype,
                                           lead=lead, alloc=alloc,
                                           seq_sharded_model=ssm_flag,
                                           ragged=ragged)
                    mtp = "model" if ssm_flag else None
                    s = kvc.MLACache(c_kv=P(None, bax, mtp, None),
                                     k_rope=P(None, bax, mtp, None),
                                     slot_pos=sp_spec(mtp),
                                     seq_sharded_model=ssm_flag)
                elif sub == "xattn":
                    if for_decode:
                        es = enc_s or s_max  # encoder context length
                        c = kvc.KVCache(
                            k=alloc((*lead, batch, hp.h_eff, es,
                                     cfg.head_dim), dtype),
                            v=alloc((*lead, batch, hp.h_eff, es,
                                     cfg.head_dim), dtype),
                            slot_pos=alloc((*lead, es), jnp.int32),
                            ring=False, seq_sharded=False)
                        s = kvc.KVCache(k=P(None, bax, tp_ax, None, None),
                                        v=P(None, bax, tp_ax, None, None),
                                        slot_pos=P(None, None),
                                        ring=False, seq_sharded=False)
                    else:
                        c, s = {}, {}
                elif sub == "mamba":
                    nh = cfg.ssm.n_heads(cfg.d_model)
                    c = kvc.make_mamba_state(batch, nh, cfg.ssm.d_state,
                                             cfg.ssm.head_dim, cfg.ssm.d_conv,
                                             dtype, lead=lead, alloc=alloc)
                    s = dict(h=P(None, bax, tp_ax, None, None),
                             conv=(P(None, bax, None, tp_ax),
                                   P(None, bax, None, tp_ax),
                                   P(None, bax, None, tp_ax)))
                elif sub == "rwkv_tmix":
                    nh = cfg.d_model // cfg.rwkv.head_dim
                    c = kvc.make_rwkv_tmix_state(batch, nh, cfg.rwkv.head_dim,
                                                 cfg.d_model, dtype,
                                                 lead=lead, alloc=alloc)
                    s = dict(wkv=P(None, bax, tp_ax, None, None),
                             shift=P(None, bax, None))
                elif sub == "rwkv_cmix":
                    c = kvc.make_rwkv_cmix_state(batch, cfg.d_model, dtype,
                                                 lead=lead, alloc=alloc)
                    s = dict(shift=P(None, bax, None))
                else:  # mlp / moe / dense_mlp / shared_mlp: stateless
                    c, s = None, None
                sec_caches.append(c)
                sec_specs.append(s)
        caches.append(tuple(sec_caches))
        specs.append(tuple(sec_specs))
    return list(caches), list(specs)


def cache_struct(cfg, batch, s_max, pcfg, **kw):
    """ShapeDtypeStruct version (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda: build_caches(cfg, batch, s_max, pcfg, **kw)[0])


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def serve_needs_fsdp(cfg: ModelConfig, pcfg: ParallelConfig,
                     hbm_bytes: float = 16e9) -> bool:
    """True when bf16 weights / tp exceed ~60% of HBM (dbrx-132b): weights
    must be flat-sharded over the data axis and gathered per layer group."""
    from repro.models.model import count_params
    return count_params(cfg) * 2 / pcfg.tp > 0.6 * hbm_bytes


def build_serve_steps(cfg: ModelConfig, mesh, pcfg: ParallelConfig, *,
                      seq_shard_data: bool = False, fsdp: bool = False,
                      fsdp_q8: bool = False):
    """Returns dict with prefill/decode shard_map'ped fns and spec builders.

    fsdp: weights stored flat-sharded over 'data', gathered per layer group
    inside the scan — the fit strategy for models whose TP-local weights
    exceed HBM (dbrx-132b on 16 GB v5e).  Costs one weight all-gather per
    step; the roofline reports it honestly as collective time.
    """
    env = make_axis_env(pcfg)
    pspecs = sharding.param_pspecs(tfm.param_specs(cfg))
    gathers = None
    if fsdp:
        from repro.parallel import fsdp as fsdp_mod
        prep_specs = jax.eval_shape(
            lambda: sharding.prepare_params_for_tp(
                tfm.init_params(cfg, jax.random.key(0)), cfg, pcfg.tp)[0])
        sec_pspecs = sharding.param_pspecs(prep_specs)["sections"]
        pspecs = dict(sharding.param_pspecs(prep_specs))
        if fsdp_q8:
            meta = fsdp_mod.sections_meta_q8(prep_specs["sections"],
                                             sec_pspecs, pcfg.tp, pcfg.dp)
            pspecs["sections"] = fsdp_mod.flat_pspecs_q8(sec_pspecs)
            gathers = fsdp_mod.make_section_gathers_q8(list(meta), env)
        else:
            meta = fsdp_mod.sections_meta(prep_specs["sections"], sec_pspecs,
                                          pcfg.tp, pcfg.dp)
            pspecs["sections"] = fsdp_mod.flat_pspecs(sec_pspecs)
            gathers = fsdp_mod.make_section_gathers(list(meta), env)
    b_axes = _batch_axes(pcfg)
    tok_spec = P(b_axes) if b_axes and not seq_shard_data else P()

    def prefill(params, tokens, caches, extra):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.family == "vlm" and "patches" in extra:
            positions = jnp.broadcast_to(
                jnp.arange(s + cfg.num_patches)[None],
                (b, s + cfg.num_patches))
        hidden, new_caches, _ = tfm.forward(
            cfg, params, tokens, env, positions=positions, caches=caches,
            frontend_embeds=extra.get("patches", extra.get("frames")),
            section_gathers=gathers)
        logits = tfm.logits_shard(cfg, params, hidden[:, -1:])
        next_tok = sampler.greedy(logits[:, 0], env, cfg.vocab_size)
        return new_caches, next_tok

    def decode(params, tokens, caches, pos):
        b = tokens.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        hidden, new_caches, _ = tfm.forward(
            cfg, params, tokens[:, None], env, positions=positions,
            caches=caches, section_gathers=gathers, unroll=True)
        logits = tfm.logits_shard(cfg, params, hidden)
        next_tok = sampler.greedy(logits[:, 0], env, cfg.vocab_size)
        return new_caches, next_tok

    return dict(prefill=prefill, decode=decode, env=env, pspecs=pspecs,
                tok_spec=tok_spec)


def build_continuous_steps(cfg: ModelConfig, pcfg: ParallelConfig, *,
                           batch_slots: int, rng_seed: int = 0):
    """Steps for the continuous-batching engine (ragged caches; see
    serving/scheduler.py for the host-side slot management).

    prefill(params, caches, tokens, length, slot, temp, top_k, top_p, seed)
        Admit ONE request into slot `slot`: reset the slot's state, run the
        prompt (right-padded to tokens.shape[1]; positions -1 beyond
        `length` so padded K/V writes are dropped), scatter the slot back
        and sample the first generated token.  Returns (caches, tok (1,)).

    decode(params, caches, tokens, pos, active, temp, top_k, top_p, seeds)
        One token for EVERY slot at its own position (all (B,)-vectors).
        Inactive slots run at position -1: their K/V writes are dropped and
        their sampled token is discarded by the host.  Returns
        (caches, toks (B,)).

    Sampling keys depend only on (request seed, absolute position), so a
    request's tokens are bit-identical whether it is decoded alone or inside
    a mixed-age continuous batch.
    """
    env = make_axis_env(pcfg)
    pspecs = sharding.param_pspecs(tfm.param_specs(cfg))
    b_axes = _batch_axes(pcfg)
    vec_spec = P(b_axes) if b_axes else P()
    dp_deg = max(1, pcfg.dp) * max(1, pcfg.pods)
    local_slots = batch_slots // dp_deg if b_axes else batch_slots
    base_key = jax.random.key(rng_seed)

    def _sample(params, hidden_last, keys, temp, top_k, top_p):
        logits = tfm.logits_shard(cfg, params, hidden_last)
        return sampler.sample_tokens(logits[:, 0], env, cfg.vocab_size,
                                     keys, temp, top_k, top_p)

    def prefill(params, caches, tokens, length, slot, temp, top_k, top_p,
                seed):
        lp = tokens.shape[1]
        slot_l = slot - env.dp_shard_index() * local_slots
        own = (slot_l >= 0) & (slot_l < local_slots)
        safe = jnp.clip(slot_l, 0, local_slots - 1)
        sub = kvc.reset_slot_state(kvc.slice_slot(caches, safe))
        ar = jnp.arange(lp)
        positions = jnp.where(ar < length, ar, -1)[None]        # (1, lp)
        hidden, sub, _ = tfm.forward(cfg, params, tokens, env,
                                     positions=positions, caches=sub)
        h_last = jax.lax.dynamic_slice_in_dim(hidden, length - 1, 1, axis=1)
        keys = sampler.request_keys(base_key, seed, length[None])
        tok = _sample(params, h_last, keys, temp, top_k, top_p)
        new_caches = kvc.insert_slot(caches, sub, safe)
        if b_axes:
            # batch sharded over data: only the owner shard keeps the write
            new_caches = jax.tree.map(
                lambda n, o: jnp.where(own, n, o), new_caches, caches)
        return new_caches, tok

    def _decode_body(params, caches, tokens, pos, active):
        positions = jnp.where(active, pos, -1)[:, None]          # (B, 1)
        hidden, caches, _ = tfm.forward(cfg, params, tokens[:, None], env,
                                        positions=positions, caches=caches,
                                        unroll=True)
        return hidden, caches

    def decode(params, caches, tokens, pos, active, temp, top_k, top_p,
               seeds):
        hidden, caches = _decode_body(params, caches, tokens, pos, active)
        keys = sampler.request_keys(base_key, seeds, pos + 1)
        toks = _sample(params, hidden, keys, temp, top_k, top_p)
        return caches, toks

    def decode_greedy(params, caches, tokens, pos, active):
        # hot default path (temperature 0 everywhere): shard-local argmax +
        # tiny all-gather; skips the full-vocab sorts/gumbel of sample_tokens
        hidden, caches = _decode_body(params, caches, tokens, pos, active)
        logits = tfm.logits_shard(cfg, params, hidden)
        toks = sampler.greedy(logits[:, 0], env, cfg.vocab_size)
        return caches, toks

    return dict(prefill=prefill, decode=decode, decode_greedy=decode_greedy,
                env=env, pspecs=pspecs, vec_spec=vec_spec,
                local_slots=local_slots)


def build_paged_steps(cfg: ModelConfig, pcfg: ParallelConfig, *,
                      batch_slots: int, rng_seed: int = 0,
                      use_pallas: Optional[bool] = None,
                      comm: Optional[CommConfig] = None,
                      tuned: bool = True, max_blocks: int = 0):
    """Steps for the paged-KV serving engine (block-pool caches; see
    serving/scheduler.PagedScheduler for the host-side block management).

    use_pallas: override ``cfg.use_pallas`` for these steps — True routes
    the paged attention read through the block-table-native Pallas kernel
    (kernels/paged_attention.py), False forces the paged_view gather
    oracle, None keeps the config's setting.  Token streams are
    bit-identical either way (tests/test_paged_kernel.py).

    comm: how the TP block-output AllReduce executes inside these steps
    (parallel/overlap.py) — None/sync keeps the monolithic psum, "overlap"
    the chunked ring (token streams bit-identical at TP<=2; distributed
    suite group `serve_comm`), "compressed" the int8 wire (bounded error,
    opt-in).  Prefill, decode and verify all thread through the same
    AxisEnv, so one setting covers the three paths.

    Block tables: every step takes a ``bt``/``bts`` table of shape
    (rows, W) where W is ANY width covering every block the step's rows
    use — the host slices the static ``max_blocks`` table down to the
    bucketed max in-use block count (scheduler.PagedServingEngine._bt_width)
    so both the gather path's traffic and the kernel's grid track actual
    pool occupancy instead of the worst case.

    prefill_chunk(params, caches, tokens, start, length, bt, temp, top_k,
                  top_p, seed)
        Run ONE chunk of ONE request's prompt: tokens (1, C) right-padded,
        `length` real tokens at absolute positions start..start+length-1.
        K/V scatters through the (1, W) block table `bt`; the chunk
        attends to everything the table already holds (earlier chunks and
        prefix-cache hits included), so long prompts interleave with decode
        in bounded per-step token budgets.  Also samples the token following
        the chunk (the host uses it only for the FINAL chunk, where it is
        the request's first generated token).  Returns (caches, tok (1,)).

    decode(params, caches, tokens, pos, active, bts, temp, top_k, top_p,
           seeds)
        One token for EVERY row at its own position through its own block
        table row.  Inactive rows run at position -1 (writes dropped, token
        discarded).  Returns (caches, toks (B,)).

    verify(params, caches, tokens, pos, active, klen, bts, temp, top_k,
           top_p, seeds)
        Speculative verification (serving/speculative.py): tokens (B, K+1)
        holds [last sampled token, draft_1..draft_K] per row, right-padded;
        row b runs its first klen[b] tokens at positions pos[b]..pos[b]+
        klen[b]-1 through its block table (padding/inactive rows at -1,
        writes dropped).  Returns (caches, tgt (B, K+1)) where tgt[b, i] is
        the token the TARGET model samples for position pos[b]+1+i — the
        exact token the plain decode step would emit given the same prefix,
        because the sampling key folds (seed, absolute position).  The host
        walks tgt against the drafts to find the accepted length
        (DESIGN.md §Speculative decoding).

    Sampling keys fold (request seed, absolute position) exactly like the
    ragged engine, so paged and ragged serving emit identical tokens — and
    speculative verification emits identical tokens to step-by-step decode.

    tuned/max_blocks: when ``tuned`` and ``max_blocks > 0``, each step keys
    the Pallas kernel's launch geometry into the committed tuning table
    (kernels/autotune.py) by its phase and the trace-time occupancy bucket
    ``bt_width / max_blocks`` — the table-width bucketing above makes the
    (phase, bucket) pair static per jit variant.  Token streams are
    bit-identical tuned-on vs tuned-off (split-K and Q-tiling are
    numerics-preserving; distributed suite group ``serve_tuned``).
    """
    if use_pallas is not None and use_pallas != cfg.use_pallas:
        cfg = cfg.replace(use_pallas=use_pallas)
    env = make_axis_env(pcfg, comm=comm)
    pspecs = sharding.param_pspecs(tfm.param_specs(cfg))
    base_key = jax.random.key(rng_seed)

    def _tune(phase, bt):
        # trace-time: bt.shape is static, so the (phase, occ-bucket) pair is
        # a static jit-cache key; ops.paged_attention resolves it against
        # the tuning table (deterministic fallback on a missing key)
        if not (tuned and max_blocks > 0 and cfg.use_pallas):
            return None
        from repro.kernels.autotune import occupancy_bucket
        occ = float(occupancy_bucket(bt.shape[-1] / max_blocks))
        return (phase, occ)

    def _sample(params, hidden_last, keys, temp, top_k, top_p):
        logits = tfm.logits_shard(cfg, params, hidden_last)
        return sampler.sample_tokens(logits[:, 0], env, cfg.vocab_size,
                                     keys, temp, top_k, top_p)

    def prefill_chunk(params, caches, tokens, start, length, bt, temp,
                      top_k, top_p, seed):
        c = tokens.shape[1]
        ar = jnp.arange(c)
        positions = jnp.where(ar < length, start + ar, -1)[None]     # (1, C)
        hidden, caches, _ = tfm.forward(cfg, params, tokens, env,
                                        positions=positions, caches=caches,
                                        block_tables=bt,
                                        attn_tune=_tune("prefill", bt))
        h_last = jax.lax.dynamic_slice_in_dim(hidden, length - 1, 1, axis=1)
        keys = sampler.request_keys(base_key, seed, (start + length)[None])
        tok = _sample(params, h_last, keys, temp, top_k, top_p)
        return caches, tok

    def _decode_body(params, caches, tokens, pos, active, bts):
        positions = jnp.where(active, pos, -1)[:, None]              # (B, 1)
        hidden, caches, _ = tfm.forward(cfg, params, tokens[:, None], env,
                                        positions=positions, caches=caches,
                                        unroll=True, block_tables=bts,
                                        attn_tune=_tune("decode", bts))
        return hidden, caches

    def decode(params, caches, tokens, pos, active, bts, temp, top_k, top_p,
               seeds):
        hidden, caches = _decode_body(params, caches, tokens, pos, active,
                                      bts)
        keys = sampler.request_keys(base_key, seeds, pos + 1)
        toks = _sample(params, hidden, keys, temp, top_k, top_p)
        return caches, toks

    def decode_greedy(params, caches, tokens, pos, active, bts):
        hidden, caches = _decode_body(params, caches, tokens, pos, active,
                                      bts)
        logits = tfm.logits_shard(cfg, params, hidden)
        toks = sampler.greedy(logits[:, 0], env, cfg.vocab_size)
        return caches, toks

    def _verify_body(params, caches, tokens, pos, active, klen, bts):
        # tokens: (B, K1); row b's valid span is its first klen[b] entries,
        # run at absolute positions pos[b] + 0..klen[b]-1.  Padding and
        # inactive rows run at -1: K/V writes drop and outputs are garbage
        # the host never reads.  Causality among the fresh tokens comes from
        # the paged attention mask (slot s attends iff s <= query position).
        b, k1 = tokens.shape
        ar = jnp.arange(k1)[None, :]
        positions = jnp.where(active[:, None] & (ar < klen[:, None]),
                              pos[:, None] + ar, -1)          # (B, K1)
        hidden, caches, _ = tfm.forward(cfg, params, tokens, env,
                                        positions=positions, caches=caches,
                                        block_tables=bts,
                                        attn_tune=_tune("verify", bts))
        return hidden, caches

    def verify(params, caches, tokens, pos, active, klen, bts, temp, top_k,
               top_p, seeds):
        hidden, caches = _verify_body(params, caches, tokens, pos, active,
                                      klen, bts)
        b, k1 = tokens.shape
        logits = tfm.logits_shard(cfg, params, hidden)        # (B, K1, Vl)
        # tgt[b, i] samples position pos[b]+1+i with the SAME key the plain
        # decode step would fold there — bit-identical verification.
        steps = (pos[:, None] + 1 + jnp.arange(k1)[None, :]).reshape(-1)
        keys = sampler.request_keys(base_key, jnp.repeat(seeds, k1), steps)
        toks = sampler.sample_tokens(
            logits.reshape(b * k1, -1), env, cfg.vocab_size, keys,
            jnp.repeat(temp, k1), jnp.repeat(top_k, k1),
            jnp.repeat(top_p, k1))
        return caches, toks.reshape(b, k1)

    def verify_greedy(params, caches, tokens, pos, active, klen, bts):
        hidden, caches = _verify_body(params, caches, tokens, pos, active,
                                      klen, bts)
        logits = tfm.logits_shard(cfg, params, hidden)
        toks = sampler.greedy(logits, env, cfg.vocab_size)    # (B, K1)
        return caches, toks

    return dict(prefill_chunk=prefill_chunk, decode=decode,
                decode_greedy=decode_greedy, verify=verify,
                verify_greedy=verify_greedy, env=env, pspecs=pspecs)


def shard_mapped(fn, mesh, in_specs, out_specs):
    """shard_map `fn` over `mesh` via the jax-version shims
    (parallel/compat.py) — convenience for callers outside this module."""
    from repro.parallel import compat
    return compat.shard_map(fn, mesh, in_specs, out_specs)
