"""Serving engine: prefill / decode steps over the sharded mesh.

``build_caches`` mirrors the assembler's section plan so cache pytrees line
up with the scanned parameter stacks.  ``build_serve_steps`` returns
shard_map'ped prefill/decode functions plus the global specs of every input —
the multi-pod dry-run lowers exactly these.

Long-context decode (long_500k, global_batch=1) cannot use the data axis for
batch DP, so the KV cache is sharded over the *sequence* on the data axis and
attention decode runs flash-decoding style (partial softmax stats combined
with a psum over 'data') — see models/attention._cached_attention.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.parallel import sharding
from repro.parallel.collectives import AxisEnv
from repro.parallel.tp import make_axis_env
from repro.serving import kv_cache as kvc
from repro.serving import sampler


def _batch_axes(pcfg: ParallelConfig):
    return ("pod", "data") if pcfg.pods > 1 else ("data",) if pcfg.dp > 1 else ()


def build_caches(cfg: ModelConfig, batch: int, s_max: int,
                 pcfg: ParallelConfig, *, for_decode: bool,
                 seq_shard_data: bool = False, enc_s: int = 0,
                 structs_only: bool = False):
    """Build (caches, cache_pspecs) as GLOBAL pytrees.

    seq_shard_data: shard KV sequence over the data axis (flash decoding) —
    used when the batch is too small for data parallelism (long_500k).
    enc_s: encoder context length for cross-attention caches (enc-dec).
    structs_only: produce ShapeDtypeStructs (dry-run — no allocation).
    """
    dtype = jnp.dtype(cfg.dtype)
    alloc = kvc.struct_alloc if structs_only else kvc._alloc_default
    plan = tfm.plan_sections(cfg)
    hp = sharding.tp_head_plan(cfg.n_heads, cfg.n_kv_heads, pcfg.tp)
    b_axes = _batch_axes(pcfg)
    # bax: mesh axes sharding the batch dim (None when batch is replicated,
    # e.g. batch=1 long-context where the data axis shards the sequence)
    bax = b_axes if (b_axes and not seq_shard_data) else None
    seq_shards = (pcfg.dp if seq_shard_data else 1)
    sspec = "data" if seq_shard_data and pcfg.dp > 1 else None
    tp_ax = "model" if pcfg.tp > 1 else None

    caches, specs = [], []
    for sec in plan:
        lead = (sec.n_groups,)
        sec_caches, sec_specs = [], []
        for kind in sec.kinds:
            for sub in tfm.subblocks_of(kind):
                if sub in ("attn", "shared_attn"):
                    c = kvc.make_kv_cache(batch, s_max, hp.kv_eff,
                                          cfg.head_dim, dtype, alloc=alloc,
                                          seq_shards=seq_shards, lead=lead)
                    s = kvc.KVCache(k=P(None, bax, tp_ax, sspec, None),
                                    v=P(None, bax, tp_ax, sspec, None),
                                    slot_pos=P(None, sspec),
                                    ring=c.ring, seq_sharded=c.seq_sharded)
                elif sub == "local_attn":
                    c = kvc.make_kv_cache(batch, s_max, hp.kv_eff,
                                          cfg.head_dim, dtype, alloc=alloc,
                                          window=cfg.sliding_window, lead=lead)
                    s = kvc.KVCache(k=P(None, bax, tp_ax, None, None),
                                    v=P(None, bax, tp_ax, None, None),
                                    slot_pos=P(None, None),
                                    ring=c.ring, seq_sharded=False)
                elif sub == "mla":
                    ssm_flag = getattr(cfg, "mla_flash_decode", False) and \
                        pcfg.tp > 1
                    c = kvc.make_mla_cache(batch, s_max, cfg.mla.kv_lora_rank,
                                           cfg.mla.qk_rope_head_dim, dtype,
                                           lead=lead, alloc=alloc,
                                           seq_sharded_model=ssm_flag)
                    mtp = "model" if ssm_flag else None
                    s = kvc.MLACache(c_kv=P(None, bax, mtp, None),
                                     k_rope=P(None, bax, mtp, None),
                                     slot_pos=P(None, mtp),
                                     seq_sharded_model=ssm_flag)
                elif sub == "xattn":
                    if for_decode:
                        es = enc_s or s_max  # encoder context length
                        c = kvc.KVCache(
                            k=alloc((*lead, batch, hp.h_eff, es,
                                     cfg.head_dim), dtype),
                            v=alloc((*lead, batch, hp.h_eff, es,
                                     cfg.head_dim), dtype),
                            slot_pos=alloc((*lead, es), jnp.int32),
                            ring=False, seq_sharded=False)
                        s = kvc.KVCache(k=P(None, bax, tp_ax, None, None),
                                        v=P(None, bax, tp_ax, None, None),
                                        slot_pos=P(None, None),
                                        ring=False, seq_sharded=False)
                    else:
                        c, s = {}, {}
                elif sub == "mamba":
                    nh = cfg.ssm.n_heads(cfg.d_model)
                    c = kvc.make_mamba_state(batch, nh, cfg.ssm.d_state,
                                             cfg.ssm.head_dim, cfg.ssm.d_conv,
                                             dtype, lead=lead, alloc=alloc)
                    s = dict(h=P(None, bax, tp_ax, None, None),
                             conv=(P(None, bax, None, tp_ax),
                                   P(None, bax, None, tp_ax),
                                   P(None, bax, None, tp_ax)))
                elif sub == "rwkv_tmix":
                    nh = cfg.d_model // cfg.rwkv.head_dim
                    c = kvc.make_rwkv_tmix_state(batch, nh, cfg.rwkv.head_dim,
                                                 cfg.d_model, dtype,
                                                 lead=lead, alloc=alloc)
                    s = dict(wkv=P(None, bax, tp_ax, None, None),
                             shift=P(None, bax, None))
                elif sub == "rwkv_cmix":
                    c = kvc.make_rwkv_cmix_state(batch, cfg.d_model, dtype,
                                                 lead=lead, alloc=alloc)
                    s = dict(shift=P(None, bax, None))
                else:  # mlp / moe / dense_mlp / shared_mlp: stateless
                    c, s = None, None
                sec_caches.append(c)
                sec_specs.append(s)
        caches.append(tuple(sec_caches))
        specs.append(tuple(sec_specs))
    return list(caches), list(specs)


def cache_struct(cfg, batch, s_max, pcfg, **kw):
    """ShapeDtypeStruct version (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda: build_caches(cfg, batch, s_max, pcfg, **kw)[0])


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def serve_needs_fsdp(cfg: ModelConfig, pcfg: ParallelConfig,
                     hbm_bytes: float = 16e9) -> bool:
    """True when bf16 weights / tp exceed ~60% of HBM (dbrx-132b): weights
    must be flat-sharded over the data axis and gathered per layer group."""
    from repro.models.model import count_params
    return count_params(cfg) * 2 / pcfg.tp > 0.6 * hbm_bytes


def build_serve_steps(cfg: ModelConfig, mesh, pcfg: ParallelConfig, *,
                      seq_shard_data: bool = False, fsdp: bool = False,
                      fsdp_q8: bool = False):
    """Returns dict with prefill/decode shard_map'ped fns and spec builders.

    fsdp: weights stored flat-sharded over 'data', gathered per layer group
    inside the scan — the fit strategy for models whose TP-local weights
    exceed HBM (dbrx-132b on 16 GB v5e).  Costs one weight all-gather per
    step; the roofline reports it honestly as collective time.
    """
    env = make_axis_env(pcfg)
    pspecs = sharding.param_pspecs(tfm.param_specs(cfg))
    gathers = None
    if fsdp:
        from repro.parallel import fsdp as fsdp_mod
        prep_specs = jax.eval_shape(
            lambda: sharding.prepare_params_for_tp(
                tfm.init_params(cfg, jax.random.key(0)), cfg, pcfg.tp)[0])
        sec_pspecs = sharding.param_pspecs(prep_specs)["sections"]
        pspecs = dict(sharding.param_pspecs(prep_specs))
        if fsdp_q8:
            meta = fsdp_mod.sections_meta_q8(prep_specs["sections"],
                                             sec_pspecs, pcfg.tp, pcfg.dp)
            pspecs["sections"] = fsdp_mod.flat_pspecs_q8(sec_pspecs)
            gathers = fsdp_mod.make_section_gathers_q8(list(meta), env)
        else:
            meta = fsdp_mod.sections_meta(prep_specs["sections"], sec_pspecs,
                                          pcfg.tp, pcfg.dp)
            pspecs["sections"] = fsdp_mod.flat_pspecs(sec_pspecs)
            gathers = fsdp_mod.make_section_gathers(list(meta), env)
    b_axes = _batch_axes(pcfg)
    tok_spec = P(b_axes) if b_axes and not seq_shard_data else P()

    def prefill(params, tokens, caches, extra):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.family == "vlm" and "patches" in extra:
            positions = jnp.broadcast_to(
                jnp.arange(s + cfg.num_patches)[None],
                (b, s + cfg.num_patches))
        hidden, new_caches, _ = tfm.forward(
            cfg, params, tokens, env, positions=positions, caches=caches,
            frontend_embeds=extra.get("patches", extra.get("frames")),
            section_gathers=gathers)
        logits = tfm.logits_shard(cfg, params, hidden[:, -1:])
        next_tok = sampler.greedy(logits[:, 0], env, cfg.vocab_size)
        return new_caches, next_tok

    def decode(params, tokens, caches, pos):
        b = tokens.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        hidden, new_caches, _ = tfm.forward(
            cfg, params, tokens[:, None], env, positions=positions,
            caches=caches, section_gathers=gathers, unroll=True)
        logits = tfm.logits_shard(cfg, params, hidden)
        next_tok = sampler.greedy(logits[:, 0], env, cfg.vocab_size)
        return new_caches, next_tok

    return dict(prefill=prefill, decode=decode, env=env, pspecs=pspecs,
                tok_spec=tok_spec)


def shard_mapped(fn, mesh, in_specs, out_specs):
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
