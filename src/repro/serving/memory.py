"""KV memory tiers: host swap pool + preemptive paged scheduling.

The paged scheduler (serving/scheduler.PagedScheduler) admits on a
*worst-case* block reservation: a request only enters when the pool could
hold its prompt plus every token it might ever generate.  That makes
mid-flight OOM impossible but leaves the pool underutilised whenever
requests finish early — which is most of the time.  This module lets the
scheduler **oversubscribe** the pool instead (DESIGN.md §KV memory tiers):

* ``PreemptivePagedScheduler`` — admission counts decode reservations
  against a virtual pool of ``oversubscribe * num_blocks`` blocks (prompt
  blocks are still physically covered at admission, so prefill never
  OOMs).  When a decode allocation finds the physical pool dry, the engine
  preempts the lowest-priority decoding row: its blocks are swapped out to
  the host tier and freed, its slot and reservation released.  The row
  resumes — same tokens, bit-identical stream — once blocks free up.

* ``SwapPool`` — the host tier: retired-block contents keyed by
  ``(seq, block-idx)``.  Payloads are raw pool bytes (plus scales for int8
  pools): the swap round-trip is bit-identical for fp pools and idempotent
  for int8 — quantized bytes move, they are never re-quantized, so a
  preempt/resume cycle cannot compound quantization error.

* ``extract_blocks`` / ``insert_blocks`` — the device <-> host block moves,
  generic over the engine's cache pytree (every ``PagedKVCache`` leaf, fp
  or int8, across scan sections).

Why preemption preserves bit-identity: a resumed row's K/V bytes are
restored verbatim into freshly allocated physical blocks, and nothing in
the forward pass observes *which* physical blocks back a logical position
— the block table indirection is total.  Sampling keys fold (seed,
absolute position), never the slot or step index, so the resumed row's
next token is computed from exactly the state the never-preempted run had
(tests/test_memory.py pins this for the paged and speculative engines,
and the TP=2 ``serve_memory`` group in tests/distributed_impl.py).

Interaction with the prefix cache: preemption releases blocks through the
same path retirement does, so a preempted row's registered prompt blocks
stay in the prefix cache (evictable at refcount 0) and keep serving hits.
Resume never consults the prefix cache — it restores the row's own bytes
into fresh blocks — which keeps the state machine two-phase and simple at
the cost of a possible duplicate of a shared prefix in the pool.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_cache import PagedKVCache
from repro.serving.scheduler import PagedScheduler, _PagedSeq


# ---------------------------------------------------------------------------
# device <-> host block movement
# ---------------------------------------------------------------------------


def _paged_leaves(caches) -> List[Tuple[int, int, PagedKVCache]]:
    """(section, entry, leaf) for every PagedKVCache in the cache pytree."""
    out = []
    for si, sec in enumerate(caches):
        for ei, c in enumerate(sec):
            if isinstance(c, PagedKVCache):
                out.append((si, ei, c))
    return out


def _block_slots(blocks: List[int], block_size: int) -> np.ndarray:
    """Flat pool token slots covered by `blocks`, block-major."""
    ids = np.asarray(blocks, np.int64)
    return (ids[:, None] * block_size + np.arange(block_size)).reshape(-1)


def extract_blocks(caches, blocks: List[int], block_size: int) -> List[Dict]:
    """Copy the contents of physical `blocks` to host, one payload dict per
    block (k/v slices, plus scales for int8 pools), each a list over the
    cache pytree's PagedKVCache leaves.  Pure reads — caches untouched."""
    import jax.numpy as jnp

    slots = jnp.asarray(_block_slots(blocks, block_size))
    per_leaf = []
    for _, _, leaf in _paged_leaves(caches):
        entry = dict(
            k=np.asarray(jnp.take(leaf.k, slots, axis=leaf.k.ndim - 2)),
            v=np.asarray(jnp.take(leaf.v, slots, axis=leaf.v.ndim - 2)),
        )
        if leaf.quant == "int8":
            ks, vs = leaf.k_scale, leaf.v_scale
            entry["k_scale"] = np.asarray(
                jnp.take(ks, slots, axis=ks.ndim - 1)
            )
            entry["v_scale"] = np.asarray(
                jnp.take(vs, slots, axis=vs.ndim - 1)
            )
        per_leaf.append(entry)
    # split block-major payloads into one entry per block
    bs = block_size
    out = []
    for bi in range(len(blocks)):
        blk_entry = []
        for entry in per_leaf:
            e = {}
            for name, arr in entry.items():
                ax = arr.ndim - (2 if name in ("k", "v") else 1)
                idx = np.arange(bi * bs, (bi + 1) * bs)
                e[name] = np.take(arr, idx, axis=ax)
            blk_entry.append(e)
        out.append(blk_entry)
    return out


def insert_blocks(
    caches, blocks: List[int], payloads: List[List[Dict]], block_size: int
):
    """Scatter swapped-out block payloads back into (possibly different)
    physical `blocks`.  Bytes land verbatim — int8 payloads are already
    quantized and are never re-quantized (the idempotence contract)."""
    import jax.numpy as jnp

    assert len(blocks) == len(payloads), "payload/block count mismatch"
    slots = jnp.asarray(_block_slots(blocks, block_size))
    leaves = _paged_leaves(caches)
    caches = [list(sec) for sec in caches]
    for li, (si, ei, leaf) in enumerate(leaves):
        merged = {}
        for name in payloads[0][li]:
            ax = payloads[0][li][name].ndim
            ax -= 2 if name in ("k", "v") else 1
            merged[name] = np.concatenate(
                [p[li][name] for p in payloads], axis=ax
            )
        kw = dict(
            k=leaf.k.at[..., slots, :].set(jnp.asarray(merged["k"])),
            v=leaf.v.at[..., slots, :].set(jnp.asarray(merged["v"])),
        )
        if leaf.quant == "int8":
            kw["k_scale"] = leaf.k_scale.at[..., slots].set(
                jnp.asarray(merged["k_scale"])
            )
            kw["v_scale"] = leaf.v_scale.at[..., slots].set(
                jnp.asarray(merged["v_scale"])
            )
        caches[si][ei] = PagedKVCache(
            block_size=leaf.block_size, quant=leaf.quant, **kw
        )
    return [tuple(sec) for sec in caches]


# ---------------------------------------------------------------------------
# host swap tier
# ---------------------------------------------------------------------------


class SwapPool:
    """Host buffer of swapped-out block contents keyed by (seq, block-idx).

    ``capacity_blocks = 0`` means unbounded (the default: host DRAM is
    orders of magnitude larger than the device pool).  A bounded pool
    raises on overflow instead of silently evicting — losing a swapped
    block would corrupt the preempted row on resume.
    """

    def __init__(self, capacity_blocks: int = 0):
        if capacity_blocks < 0:
            raise ValueError("capacity_blocks must be >= 0 (0 = unbounded)")
        self.capacity_blocks = capacity_blocks
        self._store: Dict[Tuple[int, int], List[Dict]] = {}
        self.total_swapped_out = 0  # lifetime blocks in (stats)
        self.total_swapped_in = 0  # lifetime blocks back out (stats)
        self.peak_blocks = 0

    def num_held(self) -> int:
        return len(self._store)

    def can_hold(self, n_blocks: int) -> bool:
        if not self.capacity_blocks:
            return True
        return self.num_held() + n_blocks <= self.capacity_blocks

    def put(self, seq_uid: int, block_idx: int, payload: List[Dict]):
        key = (seq_uid, block_idx)
        if key in self._store:
            raise ValueError(f"swap slot {key} already occupied")
        if not self.can_hold(1):
            raise RuntimeError(
                f"SwapPool: capacity {self.capacity_blocks} blocks "
                f"exhausted (raise --swap-blocks or lower --oversubscribe)"
            )
        self._store[key] = payload
        self.total_swapped_out += 1
        self.peak_blocks = max(self.peak_blocks, self.num_held())

    def take(self, seq_uid: int, block_idx: int) -> List[Dict]:
        key = (seq_uid, block_idx)
        if key not in self._store:
            raise ValueError(f"swap slot {key} is empty (double resume?)")
        payload = self._store.pop(key)
        self.total_swapped_in += 1
        return payload

    def put_seq(self, seq_uid: int, payloads: List[List[Dict]]):
        if not self.can_hold(len(payloads)):
            raise RuntimeError(
                f"SwapPool: capacity {self.capacity_blocks} blocks cannot "
                f"hold {len(payloads)} more (held {self.num_held()}); "
                f"raise --swap-blocks or lower --oversubscribe"
            )
        for bi, p in enumerate(payloads):
            self.put(seq_uid, bi, p)

    def take_seq(self, seq_uid: int, n_blocks: int) -> List[List[Dict]]:
        return [self.take(seq_uid, bi) for bi in range(n_blocks)]


# ---------------------------------------------------------------------------
# preemptive scheduler
# ---------------------------------------------------------------------------


class PreemptivePagedScheduler(PagedScheduler):
    """Block-granular admission with oversubscription and preemption.

    Admission differs from the base scheduler in one term: decode
    reservations are checked against ``oversubscribe * num_blocks`` virtual
    blocks instead of the physical pool (``_admission_headroom``).  Prompt
    blocks are still allocated physically at admission, so the only place
    the pool can run dry is a *decode* allocation — which the engine
    resolves by preempting a victim row (``pick_victim`` -> engine swap-out
    -> ``preempt``) and retrying.

    Victim policy: lowest ``Request.priority`` first, newest admission
    first among equals — the oldest highest-priority row is never chosen
    while any other decoding row exists, which is what guarantees global
    progress (somebody always runs to retirement, and retirement frees
    blocks for resumes).

    Preempted rows wait in FIFO order and resume before any new admission
    (``resume_ready``): a resume re-allocates the row's block count
    physically, the engine restores the swapped bytes, and decoding
    continues from exactly the saved position.
    """

    def __init__(self, *args, oversubscribe: float = 1.0, **kw):
        super().__init__(*args, **kw)
        if oversubscribe < 1.0:
            raise ValueError("oversubscribe must be >= 1.0")
        self.oversubscribe = oversubscribe
        self.preempted: Deque[_PagedSeq] = deque()
        self.preemptions = 0
        self.resumes = 0

    def _admission_headroom(self) -> int:
        return int((self.oversubscribe - 1.0) * self.allocator.num_blocks)

    # -- preemption ---------------------------------------------------------
    def pick_victim(self) -> Optional[int]:
        """Slot of the lowest-priority decoding row (newest admission
        breaks ties), or None when no decoding row exists."""
        cands = [
            (s.request.priority, -s.admit_id, i)
            for i, s in enumerate(self.slots)
            if s is not None and s.decoding and s.tokens
        ]
        return min(cands)[2] if cands else None

    def preempt(self, slot: int) -> _PagedSeq:
        """Release a decoding row's blocks, slot, and reservation; park it
        on the resume queue.  The engine must have captured the block
        contents (extract_blocks -> SwapPool) BEFORE calling this — the
        freed blocks may be rewritten by the very next allocation."""
        seq = self.slots[slot]
        if seq is None or not seq.decoding or not seq.tokens:
            # exception, not assert: must survive python -O (same hardening
            # standard as BlockAllocator's guards)
            raise ValueError(f"slot {slot} is not a decoding row")
        seq.swapped_blocks = len(seq.blocks)
        self.total_reserved -= seq.reserved
        for blk in seq.blocks:
            self._release_block(blk)
        seq.blocks = []
        self.slots[slot] = None
        self.preempted.append(seq)
        self.preemptions += 1
        return seq

    def resume_ready(self) -> Optional[Tuple[int, _PagedSeq]]:
        """Re-admit the oldest preempted row if a slot and its physical
        block count fit; allocates the blocks and restores the reservation.
        Returns (slot, seq) — the engine then restores the swapped bytes
        into ``seq.blocks`` — or None."""
        if not self.preempted:
            return None
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return None
        seq = self.preempted[0]
        need = seq.swapped_blocks
        ev = self.prefix.num_evictable() if self.prefix is not None else 0
        if self.allocator.num_free() + ev < need:
            return None
        budget = self.available_blocks() + self._admission_headroom()
        if budget < need + seq.reserved:
            return None
        self.preempted.popleft()
        seq.blocks = [self._alloc_block() for _ in range(need)]
        seq.fresh_blocks += need
        seq.swapped_blocks = 0
        self.total_reserved += seq.reserved
        slot = free[0]
        self.slots[slot] = seq
        self.resumes += 1
        return slot, seq

    # -- bookkeeping --------------------------------------------------------
    def has_work(self) -> bool:
        return super().has_work() or bool(self.preempted)

    def reset_stats(self):
        """Zero counters (bench warmup); preempted rows are untouched."""
        super().reset_stats()
        self.preemptions = 0
        self.resumes = 0

    def stats(self):
        s = super().stats()
        s.update(
            preemptions=self.preemptions,
            resumes=self.resumes,
            preempted_waiting=len(self.preempted),
            oversubscribe=self.oversubscribe,
        )
        return s
