"""Sampling over vocab-sharded logits.

``greedy`` and ``sample`` (Gumbel-max) never materialise the full logits —
each TP shard works on its vocab slice and a tiny all-gather combines the
winners.  ``sample_tokens`` adds per-request temperature / top-k / top-p
with seeded PRNG for the continuous-batching engine; top-k/top-p need a
global sort, so under TP it gathers the full (B, V) logits first — an
accepted cost: B is the slot count and the gather is off the ladder's
critical path (it happens after the final block's AllReduce).

Because every key folds (request seed, absolute position), sampling is a
deterministic function of (logits, seed, position) — the property the
speculative verify step exploits to make draft acceptance exact
(DESIGN.md §Speculative decoding).  ``rejection_sample`` is the standard
stochastic accept rule for general (target, draft) distribution pairs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.collectives import AxisEnv

NEG_INF = -1e30
GREEDY_EPS = 1e-5   # temperature at or below this means argmax decoding


def _mask_padded(logits_shard, env: AxisEnv, true_vocab: int):
    v = logits_shard.shape[-1]
    col = jnp.arange(v) + env.model_axis_index() * v
    return jnp.where(col < true_vocab, logits_shard.astype(jnp.float32), -1e30)


def greedy(logits_shard, env: AxisEnv, true_vocab: int):
    """Argmax across vocab shards: local top-1 then a tiny all-gather."""
    lf = _mask_padded(logits_shard, env, true_vocab)
    v = lf.shape[-1]
    loc_val = jnp.max(lf, axis=-1)                       # (B,) or (B,S)
    loc_idx = jnp.argmax(lf, axis=-1) + env.model_axis_index() * v
    if env.model:
        vals = jax.lax.all_gather(loc_val, env.model)    # (tp, ...)
        idxs = jax.lax.all_gather(loc_idx, env.model)
        win = jnp.argmax(vals, axis=0)
        return jnp.take_along_axis(idxs, win[None], axis=0)[0]
    return loc_idx


def sample(logits_shard, env: AxisEnv, true_vocab: int, key,
           temperature: float = 1.0):
    """Gumbel-max sampling: per-token Gumbel noise keyed by GLOBAL vocab id,
    so shards draw consistent noise and the global argmax is a faithful
    categorical sample."""
    lf = _mask_padded(logits_shard, env, true_vocab) / max(temperature, 1e-6)
    shard = env.model_axis_index()
    # fold the shard id into the key so each shard draws its own columns
    k = jax.random.fold_in(key, shard)
    g = jax.random.gumbel(k, lf.shape, jnp.float32)
    return greedy((lf + g), AxisEnv(model=env.model), true_vocab=10**9) \
        if env.model else jnp.argmax(lf + g, axis=-1)


# ---------------------------------------------------------------------------
# per-request sampling (continuous batching)
# ---------------------------------------------------------------------------

def request_keys(base_key, seeds, steps):
    """Per-row PRNG keys: fold the request seed then the absolute position of
    the token being generated.  A request's random stream therefore depends
    only on (seed, position) — NOT on which slot it occupies or which other
    requests share the batch, which is what makes continuous-batching output
    bit-identical to isolated decoding."""
    return jax.vmap(lambda s, t: jax.random.fold_in(
        jax.random.fold_in(base_key, s), t))(seeds, steps)


def _apply_top_k(logits, top_k):
    """Mask all but each row's top-k logits.  top_k: (B,) int32; <=0 keeps
    the full distribution."""
    v = logits.shape[-1]
    sorted_desc = -jnp.sort(-logits, axis=-1)
    kk = jnp.clip(top_k, 1, v)
    thresh = jnp.take_along_axis(sorted_desc, kk[:, None] - 1, axis=-1)
    keep = (logits >= thresh) | (top_k <= 0)[:, None]
    return jnp.where(keep, logits, NEG_INF)


def _apply_top_p(logits, top_p):
    """Nucleus filtering.  top_p: (B,) float; >=1 keeps everything.  A row
    keeps the smallest prefix of the sorted distribution whose exclusive
    cumulative probability is < top_p (the top-1 token always survives)."""
    idx = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, idx, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum_excl = jnp.cumsum(probs, axis=-1) - probs
    keep_sorted = (cum_excl < top_p[:, None]) | (top_p >= 1.0)[:, None]
    inv = jnp.argsort(idx, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return jnp.where(keep, logits, NEG_INF)


# ---------------------------------------------------------------------------
# speculative decoding (serving/speculative.py)
# ---------------------------------------------------------------------------

def rejection_sample(keys, target_logits, draft_logits, draft_tokens):
    """Standard speculative-sampling accept rule (Leviathan et al. 2023).

    Per batch row: accept ``draft_tokens[b]`` with probability
    ``min(1, p(draft) / q(draft))`` where p/q are the target/draft softmax
    distributions; on rejection, resample from the residual
    ``normalize(max(p - q, 0))``.  The emitted token is then an exact sample
    from p regardless of how bad q is — the classic correctness guarantee.

    keys: (B,) typed PRNG keys (one per row; split internally for the
    accept test and the residual draw).
    target_logits / draft_logits: (B, V) full, unsharded logits.
    draft_tokens: (B,) int32 proposals (assumed drawn from q, so
    q(draft) > 0).
    Returns (accepted (B,) bool, tokens (B,) int32).

    The ENGINE does not call this: with Gumbel noise pinned to (seed,
    position) the coupled-randomness form of this rule degenerates to exact
    token match (DESIGN.md §Speculative decoding), which is what the verify
    step implements.  This standalone form is the general-distribution API
    and is pinned empirically by tests/test_speculative.py.
    """
    p = jax.nn.softmax(target_logits.astype(jnp.float32), axis=-1)
    q = jax.nn.softmax(draft_logits.astype(jnp.float32), axis=-1)
    pd = jnp.take_along_axis(p, draft_tokens[:, None], axis=-1)[:, 0]
    qd = jnp.take_along_axis(q, draft_tokens[:, None], axis=-1)[:, 0]
    k_acc = jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys)
    k_res = jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys)
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(k_acc)
    accepted = u * qd <= pd                       # u <= p/q without division
    residual = jnp.maximum(p - q, 0.0)
    mass = jnp.sum(residual, axis=-1, keepdims=True)
    # p == q: residual is empty but acceptance is certain; fall back to p so
    # the (never-used) resample branch still has a valid distribution
    residual = jnp.where(mass > 0, residual / jnp.maximum(mass, 1e-30), p)
    g = jax.vmap(lambda k: jax.random.gumbel(k, p.shape[-1:], jnp.float32))(
        k_res)
    resampled = jnp.argmax(jnp.log(jnp.maximum(residual, 1e-30)) + g,
                           axis=-1)
    return accepted, jnp.where(accepted, draft_tokens, resampled)


def sample_tokens(logits_shard, env: AxisEnv, true_vocab: int, keys,
                  temperature, top_k, top_p):
    """Sample one token per batch row with per-row parameters.

    logits_shard: (B, V_local) — this TP shard's vocab slice.
    keys: (B,) typed PRNG keys (see ``request_keys``).
    temperature/top_k/top_p: (B,) vectors.  temperature <= GREEDY_EPS decodes
    greedily for that row (exactly matching ``greedy``).
    Returns (B,) int32 global token ids, identical on every shard.
    """
    lf = _mask_padded(logits_shard, env, true_vocab)
    if env.model:
        # full vocab in shard order: shard i owns columns [i*v, (i+1)*v)
        lf = jax.lax.all_gather(lf, env.model, axis=-1, tiled=True)
    greedy_tok = jnp.argmax(lf, axis=-1)

    scaled = lf / jnp.maximum(temperature, GREEDY_EPS)[:, None]
    filtered = _apply_top_p(_apply_top_k(scaled, top_k), top_p)
    gumbel = jax.vmap(
        lambda k: jax.random.gumbel(k, (lf.shape[-1],), jnp.float32))(keys)
    sampled_tok = jnp.argmax(filtered + gumbel, axis=-1)
    return jnp.where(temperature <= GREEDY_EPS, greedy_tok, sampled_tok)
