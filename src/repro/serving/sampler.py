"""Sampling over vocab-sharded logits (full logits never materialised)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.collectives import AxisEnv


def _mask_padded(logits_shard, env: AxisEnv, true_vocab: int):
    v = logits_shard.shape[-1]
    col = jnp.arange(v) + env.model_axis_index() * v
    return jnp.where(col < true_vocab, logits_shard.astype(jnp.float32), -1e30)


def greedy(logits_shard, env: AxisEnv, true_vocab: int):
    """Argmax across vocab shards: local top-1 then a tiny all-gather."""
    lf = _mask_padded(logits_shard, env, true_vocab)
    v = lf.shape[-1]
    loc_val = jnp.max(lf, axis=-1)                       # (B,) or (B,S)
    loc_idx = jnp.argmax(lf, axis=-1) + env.model_axis_index() * v
    if env.model:
        vals = jax.lax.all_gather(loc_val, env.model)    # (tp, ...)
        idxs = jax.lax.all_gather(loc_idx, env.model)
        win = jnp.argmax(vals, axis=0)
        return jnp.take_along_axis(idxs, win[None], axis=0)[0]
    return loc_idx


def sample(logits_shard, env: AxisEnv, true_vocab: int, key,
           temperature: float = 1.0):
    """Gumbel-max sampling: per-token Gumbel noise keyed by GLOBAL vocab id,
    so shards draw consistent noise and the global argmax is a faithful
    categorical sample."""
    lf = _mask_padded(logits_shard, env, true_vocab) / max(temperature, 1e-6)
    v = lf.shape[-1]
    shard = env.model_axis_index()
    # fold the shard id into the key so each shard draws its own columns
    k = jax.random.fold_in(key, shard)
    g = jax.random.gumbel(k, lf.shape, jnp.float32)
    return greedy((lf + g), AxisEnv(model=env.model), true_vocab=10**9) \
        if env.model else jnp.argmax(lf + g, axis=-1)
