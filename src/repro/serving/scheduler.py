"""Continuous-batching request schedulers and serving engines.

The paper's wall-clock win is a per-step property; this module is what makes
it matter under real traffic: a fixed pool of ``batch_slots`` decode slots
stays full by admitting variable-length requests as they arrive, interleaving
prefill of new requests with decode of in-flight ones, retiring sequences on
EOS or length cap, and refilling freed slots (DESIGN.md §Serving).

Split of responsibilities:

* ``Scheduler``  — pure host-side bookkeeping (FIFO admission queue, slot
  lifecycle, retirement rules).  No jax; unit-testable in microseconds.
* ``ContinuousServingEngine`` — owns the device state (ragged caches, jitted
  prefill/decode from ``engine.build_continuous_steps``) and drives the
  scheduler.  One jitted decode graph serves a mixed-age batch under any
  ``ResidualMode`` and TP/DP sharding.
* ``PagedScheduler`` / ``PagedServingEngine`` — the paged-KV path
  (DESIGN.md §Paged KV): requests are admitted on *block availability*
  instead of whole-slot ``s_max`` reservation, long prompts prefill in
  bounded per-step token chunks that interleave with in-flight decode, and
  shared prompt prefixes reuse physical blocks via hash-chained prefix
  matching.  The ragged path above stays as the equivalence oracle.
* ``SpeculativePagedEngine`` (serving/speculative.py) — subclasses the
  paged engine, replacing its decode phase with draft-and-verify
  (DESIGN.md §Speculative decoding); the hooks it relies on here are
  ``_decode_phase``, ``ensure_blocks_through`` and ``rollback_blocks``.
* ``PreemptivePagedScheduler`` / ``SwapPool`` (serving/memory.py) — the KV
  memory tiers (DESIGN.md §KV memory tiers): admission may oversubscribe
  the pool (``oversubscribe`` / ``swap_blocks`` engine kwargs); on decode
  allocation pressure the engine swaps the lowest-priority decoding row
  out to the host tier (``_swap_out`` / ``_ensure_through``) and resumes
  it verbatim later (``_resume_preempted``) — output streams stay
  bit-identical (tests/test_memory.py).  ``kv_quant="int8"`` additionally
  stores the pool quantized (2x+ rows per pool byte).

Determinism contract: a request's output tokens depend only on (prompt,
sampling params, seed) — never on which slot it lands in, what else is in
flight, or whether speculation is enabled — because attention masks key on
per-row ``slot_pos`` and sampling keys fold (seed, absolute position).
``tests/test_scheduler.py`` asserts bit-identity between continuous and
isolated decoding; ``tests/test_paged.py`` asserts it between the paged
and ragged engines; ``tests/test_speculative.py`` between speculative and
plain decode.  (MoE models with finite expert capacity are the documented
exception: routing competes across the batch, so outputs can differ at
capacity.)
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.  temperature <= sampler.GREEDY_EPS
    decodes greedily; top_k <= 0 and top_p >= 1 disable the filters."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


@dataclass
class Request:
    """One generation request: `prompt` is a token-id list (non-empty,
    at most s_max - 1 long), `max_new_tokens` >= 1 the generation budget,
    `sampling` the per-request sampling controls.  `priority` only matters
    under the preemptive scheduler (serving/memory.py): lower-priority
    rows are preempted first when the pool runs dry."""
    rid: int
    prompt: List[int]
    max_new_tokens: int
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival: float = 0.0          # bench bookkeeping (seconds or step index)
    priority: int = 0


@dataclass
class _Slot:
    request: Request
    pos: int                      # absolute position of the LAST sampled token
    tokens: List[int]             # generated so far (first token from prefill)


@dataclass
class FinishedRequest:
    """A retired request: `tokens` is everything generated (first token
    from prefill) and `finish_reason` why it stopped."""
    rid: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str            # "eos" | "length" | "cache_full"


class Scheduler:
    """FIFO admission into a fixed slot pool, with per-slot retirement.

    The scheduler never touches arrays: callers report sampled tokens via
    ``start``/``observe`` and receive retirement decisions back.
    """

    def __init__(self, n_slots: int, s_max: int, eos_id: Optional[int] = None,
                 max_prefills_per_step: int = 1):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.s_max = s_max
        self.eos_id = eos_id
        self.max_prefills_per_step = max_prefills_per_step
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.finished: List[FinishedRequest] = []

    # -- submission ---------------------------------------------------------
    def submit(self, request: Request):
        if not request.prompt:
            raise ValueError(f"request {request.rid}: empty prompt")
        if len(request.prompt) > self.s_max - 1:
            raise ValueError(
                f"request {request.rid}: prompt {len(request.prompt)} "
                f"does not fit s_max={self.s_max} (need prompt <= s_max-1)")
        self.queue.append(request)

    # -- admission ----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admissions(self) -> List[Tuple[int, Request]]:
        """Pick (slot, request) pairs to prefill this step: FIFO order, at
        most ``max_prefills_per_step`` (so one queue burst cannot starve
        in-flight decodes — prefill interleaves with decode)."""
        out = []
        for slot in self.free_slots()[:self.max_prefills_per_step]:
            if not self.queue:
                break
            out.append((slot, self.queue.popleft()))
        return out

    def start(self, slot: int, request: Request, first_token: int) -> bool:
        """Mark `slot` active after its prefill sampled `first_token`.
        Returns True if the request retired immediately."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        self.slots[slot] = _Slot(request=request, pos=len(request.prompt),
                                 tokens=[first_token])
        return self._maybe_retire(slot)

    # -- decode bookkeeping -------------------------------------------------
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def observe(self, slot: int, token: int) -> bool:
        """Record one decoded token for an active slot.  Returns True if the
        request retired (slot is freed)."""
        st = self.slots[slot]
        assert st is not None, f"slot {slot} inactive"
        st.pos += 1
        st.tokens.append(token)
        return self._maybe_retire(slot)

    def _maybe_retire(self, slot: int) -> bool:
        st = self.slots[slot]
        reason = None
        if self.eos_id is not None and st.tokens[-1] == self.eos_id:
            reason = "eos"
        elif len(st.tokens) >= st.request.max_new_tokens:
            reason = "length"
        elif st.pos + 1 >= self.s_max:
            # the NEXT decode would write K/V past the last cache slot
            reason = "cache_full"
        if reason is None:
            return False
        self.finished.append(FinishedRequest(
            rid=st.request.rid, prompt=list(st.request.prompt),
            tokens=list(st.tokens), finish_reason=reason))
        self.slots[slot] = None
        return True

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)


# ---------------------------------------------------------------------------
# device-side engine
# ---------------------------------------------------------------------------

def _bucket(n: int, lo: int = 16) -> int:
    """Round the prompt length up to a power-of-two bucket, bounding jit
    recompiles to O(log s_max) prefill shapes."""
    b = lo
    while b < n:
        b *= 2
    return b


class _ServingEngineBase:
    """Host-side machinery shared by the ragged and paged engines: per-slot
    decode vectors, the greedy/sampled decode dispatch with the
    observe/retire loop, and queue draining.  Subclasses own admission and
    prefill strategy plus the jitted step wiring (``self._decode`` /
    ``self._decode_greedy`` signatures differ only by the extra per-step
    args a subclass passes through ``_decode_step``)."""

    def _init_host_vectors(self, batch_slots: int):
        np = self._np
        z = lambda dt, fill=0: np.full((batch_slots,), fill, dt)
        self._tokens = z(np.int32)
        self._pos = z(np.int32)
        self._active = z(bool, False)
        self._temp = z(np.float32, 0.0)
        self._top_k = z(np.int32)
        self._top_p = z(np.float32, 1.0)
        self._seeds = z(np.int32)

    def _start_decode_slot(self, slot: int, req: Request, tok: int):
        """Arm a slot's decode vectors after its prefill sampled `tok`."""
        sp = req.sampling
        self._tokens[slot] = tok
        self._pos[slot] = len(req.prompt)
        self._active[slot] = True
        self._temp[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._seeds[slot] = sp.seed

    def _decode_step(self, live: List[int], extra=()) -> List[Tuple[int, int]]:
        """One batched decode of every in-flight slot; returns (rid, token)
        events.  `extra` is appended after the `active` argument (the paged
        engine passes its block tables there)."""
        jnp, np = self._jnp, self._np
        from repro.serving.sampler import GREEDY_EPS
        base = (self.params, self.caches,
                jnp.asarray(self._tokens), jnp.asarray(self._pos),
                jnp.asarray(self._active), *extra)
        if all(self._temp[s] <= GREEDY_EPS for s in live):
            # hot default path: every in-flight request decodes greedily
            self.caches, toks = self._decode_greedy(*base)
        else:
            self.caches, toks = self._decode(
                *base, jnp.asarray(self._temp), jnp.asarray(self._top_k),
                jnp.asarray(self._top_p), jnp.asarray(self._seeds))
        toks = np.asarray(toks)
        events: List[Tuple[int, int]] = []
        for slot in live:
            tok = int(toks[slot])
            rid = self.scheduler.slots[slot].request.rid
            events.append((rid, tok))
            if self.scheduler.observe(slot, tok):
                self._active[slot] = False
            else:
                self._tokens[slot] = tok
                self._pos[slot] += 1
        return events

    # -- public API ---------------------------------------------------------
    def submit(self, request: Request):
        self.scheduler.submit(request)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def run(self) -> Dict[int, FinishedRequest]:
        """Drain the queue completely; returns rid -> FinishedRequest."""
        while self.has_work():
            self.step()
        return {f.rid: f for f in self.scheduler.finished}


class ContinuousServingEngine(_ServingEngineBase):
    """Drives ``Scheduler`` against the jitted ragged-cache steps.

    One ``step()`` = up to ``max_prefills_per_step`` prefills (admitting new
    requests into freed slots) + one batched decode of every in-flight slot.
    """

    def __init__(self, cfg, params, *, batch_slots: int, s_max: int,
                 pcfg=None, mesh=None, eos_id: Optional[int] = None,
                 rng_seed: int = 0, max_prefills_per_step: int = 1,
                 prefill_bucket_min: int = 16):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import ParallelConfig
        from repro.parallel import compat
        from repro.serving import engine as engine_mod

        if cfg.encoder_layers or cfg.family == "vlm":
            raise NotImplementedError(
                "continuous batching currently targets decoder-only token "
                "models (enc-dec / VLM requests carry per-request frontend "
                "state the slot pool does not manage yet)")

        self._jnp, self._np = jnp, np
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.s_max = s_max
        self.prefill_bucket_min = prefill_bucket_min
        # Recurrent sub-blocks (mamba/rwkv) consume every input token into
        # their state regardless of position masking, so right-padding the
        # prompt would corrupt the state the decode steps start from.  Those
        # families prefill at EXACT length (one jit compile per distinct
        # prompt length) instead of power-of-two buckets.
        from repro.models import transformer as _tfm
        self._exact_prefill = any(
            sub in ("mamba", "rwkv_tmix", "rwkv_cmix")
            for kind in _tfm.effective_kinds(cfg)
            for sub in _tfm.subblocks_of(kind))
        pcfg = pcfg if pcfg is not None else ParallelConfig()
        self.scheduler = Scheduler(batch_slots, s_max, eos_id=eos_id,
                                   max_prefills_per_step=max_prefills_per_step)

        steps = engine_mod.build_continuous_steps(
            cfg, pcfg, batch_slots=batch_slots, rng_seed=rng_seed)
        self.caches, cache_specs = engine_mod.build_caches(
            cfg, batch_slots, s_max, pcfg, for_decode=False, ragged=True)

        if mesh is not None and pcfg.world > 1:
            vs, ps = steps["vec_spec"], steps["pspecs"]
            scalar = P()
            prefill = compat.shard_map(
                steps["prefill"], mesh,
                (ps, cache_specs, scalar, scalar, scalar, scalar, scalar,
                 scalar, scalar),
                (cache_specs, scalar))
            decode = compat.shard_map(
                steps["decode"], mesh,
                (ps, cache_specs, vs, vs, vs, vs, vs, vs, vs),
                (cache_specs, vs))
            decode_greedy = compat.shard_map(
                steps["decode_greedy"], mesh,
                (ps, cache_specs, vs, vs, vs), (cache_specs, vs))
            self._mesh_ctx = lambda: compat.set_mesh(mesh)
        else:
            prefill, decode = steps["prefill"], steps["decode"]
            decode_greedy = steps["decode_greedy"]
            import contextlib
            self._mesh_ctx = contextlib.nullcontext
        self._prefill = jax.jit(prefill, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._decode_greedy = jax.jit(decode_greedy, donate_argnums=(1,))

        # host-side per-slot vectors fed to the decode step
        self._init_host_vectors(batch_slots)

    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration.  Returns (rid, token) events emitted."""
        events: List[Tuple[int, int]] = []
        with self._mesh_ctx():
            for slot, req in self.scheduler.admissions():
                tok = self._run_prefill(slot, req)
                events.append((req.rid, tok))
                if not self.scheduler.start(slot, req, tok):
                    self._start_decode_slot(slot, req, tok)

            live = self.scheduler.active_slots()
            if live:
                events.extend(self._decode_step(live))
        return events

    # -- internals ----------------------------------------------------------
    def _run_prefill(self, slot: int, req: Request) -> int:
        jnp, np = self._jnp, self._np
        sp = req.sampling
        length = len(req.prompt)
        lb = length if self._exact_prefill else \
            _bucket(length, self.prefill_bucket_min)
        toks = np.zeros((1, lb), np.int32)
        toks[0, :length] = req.prompt
        self.caches, tok = self._prefill(
            self.params, self.caches, jnp.asarray(toks),
            jnp.asarray(length, jnp.int32), jnp.asarray(slot, jnp.int32),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray([sp.seed], jnp.int32))
        return int(tok[0])


# ---------------------------------------------------------------------------
# paged-KV scheduler (DESIGN.md §Paged KV)
# ---------------------------------------------------------------------------

@dataclass
class _PagedSeq:
    request: Request
    admit_id: int                 # admission order (prefill chunk FIFO)
    blocks: List[int]             # physical block ids, logical order
    block_hashes: List[int]       # chain hashes of the prompt's FULL blocks
    num_cached: int               # prompt tokens served from the prefix cache
    filled: int                   # prompt tokens whose K/V is on device
    reserved: int                 # decode blocks reserved but not yet alloc'd
    registered: int = 0           # prompt blocks handled by the prefix cache
    fresh_blocks: int = 0         # blocks newly allocated for this request
    pos: int = -1                 # last sampled token's position (decode)
    tokens: List[int] = field(default_factory=list)
    swapped_blocks: int = 0       # blocks held in the swap tier (preempted)

    @property
    def decoding(self) -> bool:
        return self.filled >= len(self.request.prompt)


class PagedScheduler:
    """Block-granular admission, chunked prefill, and prefix reuse.

    Pure host bookkeeping over a ``BlockAllocator`` + ``PrefixCache`` (both
    in serving/kv_cache.py) — no jax, unit-testable in microseconds.

    Admission policy (no mid-flight OOM by construction): a request is
    admitted only when the pool can cover its *worst case* —
    ``ceil(min(prompt + max_new - 1, s_max - 1) / block_size)`` blocks,
    minus prefix-cache hits.  Prompt blocks are allocated at admission;
    decode blocks are counted against a reservation and materialised lazily,
    so ``num_free - reserved`` is the budget every admission checks.
    Admission is strict FIFO (head-of-line blocking, same as the ragged
    scheduler): a too-big head request waits rather than being overtaken.

    Copy-on-write rule: a block is writable only while its refcount is
    exactly 1.  Prefix hits cover FULL blocks only and always leave the
    final prompt token uncached, so every sequence ends its table with an
    exclusively-owned block and divergence *recomputes into a fresh block*
    instead of mutating a shared one.  ``prefill_work`` and
    ``ensure_decode_blocks`` assert the invariant on every block they are
    about to write.
    """

    def __init__(self, n_slots: int, s_max: int, allocator,
                 prefix_cache=None, eos_id: Optional[int] = None,
                 max_prefill_tokens: int = 128):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if max_prefill_tokens < 1:
            raise ValueError("need a positive prefill token budget")
        self.n_slots = n_slots
        self.s_max = s_max
        self.eos_id = eos_id
        self.max_prefill_tokens = max_prefill_tokens
        self.allocator = allocator
        self.block_size = allocator.block_size
        self.max_blocks = -(-s_max // self.block_size)
        self.prefix = prefix_cache
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[_PagedSeq]] = [None] * n_slots
        self.finished: List[FinishedRequest] = []
        self.total_reserved = 0
        self._admit_seq = 0
        # stats: prefix-hit rate + per-request block economy (tests/bench)
        self.prefix_hit_tokens = 0
        self.prefill_tokens = 0        # prompt tokens actually computed
        self.deferred_admissions = 0   # head-of-line waits on blocks
        self._alloc_base = 0           # allocator.total_allocs at last reset
        self.request_stats: Dict[int, Dict[str, int]] = {}

    # -- submission ---------------------------------------------------------
    def submit(self, request: Request):
        if not request.prompt:
            raise ValueError(f"request {request.rid}: empty prompt")
        if len(request.prompt) > self.s_max - 1:
            raise ValueError(
                f"request {request.rid}: prompt {len(request.prompt)} "
                f"does not fit s_max={self.s_max} (need prompt <= s_max-1)")
        worst = self._worst_case_blocks(request)
        if worst > self.allocator.num_blocks:
            # admission can never succeed (even an empty pool is too small):
            # reject here instead of deferring forever at the queue head
            raise ValueError(
                f"request {request.rid}: needs {worst} KV blocks worst-case "
                f"but the pool only has {self.allocator.num_blocks}")
        self.queue.append(request)

    # -- block budget -------------------------------------------------------
    def _blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def _worst_case_blocks(self, req: Request) -> int:
        """Blocks a request may write: prompt + generated tokens (the last
        sampled token is never written), capped by the s_max retire rule."""
        return self._blocks_for(
            min(len(req.prompt) + req.max_new_tokens - 1, self.s_max - 1))

    def available_blocks(self) -> int:
        """Blocks an admission could still claim: clean free list, plus
        evictable prefix-cached blocks, minus outstanding reservations."""
        ev = self.prefix.num_evictable() if self.prefix is not None else 0
        return self.allocator.num_free() + ev - self.total_reserved

    def _admission_headroom(self) -> int:
        """Virtual blocks admission may count beyond the physical pool.
        0 here (reservations are fully backed — no mid-flight OOM by
        construction); the preemptive scheduler (serving/memory.py)
        returns the oversubscription slack instead."""
        return 0

    def _alloc_block(self) -> int:
        if self.allocator.num_free() == 0 and self.prefix is not None and \
                self.prefix.num_evictable():
            self.allocator.free(self.prefix.pop_lru())   # reclaim LRU cached
        return self.allocator.alloc()

    def _release_block(self, blk: int):
        if self.allocator.decref(blk) == 0:
            if self.prefix is not None and self.prefix.contains_block(blk):
                self.prefix.mark_evictable(blk)          # stays reusable
            else:
                self.allocator.free(blk)

    # -- admission ----------------------------------------------------------
    def _match_prefix(self, prompt: List[int], hashes: List[int]):
        """Longest chain of FULL cached blocks, capped so the last prompt
        token is always recomputed (its hidden state seeds sampling)."""
        hits: List[int] = []
        if self.prefix is None:
            return hits
        for h in hashes[: (len(prompt) - 1) // self.block_size]:
            blk = self.prefix.lookup(h)
            if blk is None:
                break
            hits.append(blk)
        return hits

    def admissions(self) -> List[Tuple[int, Request]]:
        """Admit FIFO-queue heads while a slot AND their block budget fit.
        Prompt blocks (minus prefix hits) are allocated here; decode blocks
        are reserved.  Returns newly admitted (slot, request) pairs."""
        out: List[Tuple[int, Request]] = []
        free = [i for i, s in enumerate(self.slots) if s is None]
        while self.queue and free:
            req = self.queue[0]
            lp = len(req.prompt)
            bs = self.block_size
            hashes: List[int] = []
            h = None
            for i in range(lp // bs):
                h = self.prefix.chain(h, req.prompt[i * bs:(i + 1) * bs]) \
                    if self.prefix is not None else 0
                hashes.append(h)
            hits = self._match_prefix(req.prompt, hashes)
            n_prompt = self._blocks_for(lp)
            need_now = n_prompt - len(hits)
            need_later = self._worst_case_blocks(req) - n_prompt
            # budget check BEFORE committing the hits: evictable hit blocks
            # are about to be pinned, so they cannot also fund allocations
            # (and a failed attempt must not touch the LRU order).  The
            # reservation term may draw on oversubscription headroom
            # (preemptive scheduler), but the prompt blocks allocated RIGHT
            # NOW must be physically available either way.
            ev = self.prefix.num_evictable() if self.prefix is not None else 0
            ev_hits = sum(1 for b in hits if self.allocator.refcount(b) == 0)
            if (self.available_blocks() + self._admission_headroom() -
                    ev_hits < need_now + need_later) or \
                    (self.allocator.num_free() + ev - ev_hits < need_now):
                self.deferred_admissions += 1
                break                             # strict FIFO: head waits
            for blk in hits:
                if self.allocator.refcount(blk) == 0:
                    self.prefix.revive(blk)
                self.allocator.incref(blk)
            self.queue.popleft()
            slot = free.pop(0)
            seq = _PagedSeq(
                request=req, admit_id=self._admit_seq, blocks=list(hits),
                block_hashes=hashes, num_cached=len(hits) * bs,
                filled=len(hits) * bs, reserved=need_later,
                registered=len(hits))
            self._admit_seq += 1
            for _ in range(need_now):
                seq.blocks.append(self._alloc_block())
                seq.fresh_blocks += 1
            self.total_reserved += need_later
            self.prefix_hit_tokens += seq.num_cached
            self.slots[slot] = seq
            out.append((slot, req))
        return out

    # -- chunked prefill ----------------------------------------------------
    def prefill_work(self) -> List[Tuple[int, List[int], int]]:
        """Chunks to run this step: (slot, prompt_chunk, start) triples in
        admission order, bounded by ``max_prefill_tokens`` in total so one
        long prompt cannot starve in-flight decodes."""
        budget = self.max_prefill_tokens
        work: List[Tuple[int, List[int], int]] = []
        prefilling = sorted(
            ((i, s) for i, s in enumerate(self.slots)
             if s is not None and not s.decoding),
            key=lambda t: t[1].admit_id)
        for slot, seq in prefilling:
            if budget <= 0:
                break
            lp = len(seq.request.prompt)
            chunk = min(budget, lp - seq.filled)
            lo, hi = seq.filled // self.block_size, \
                (seq.filled + chunk - 1) // self.block_size
            for bi in range(lo, hi + 1):          # COW write-ownership guard
                assert self.allocator.refcount(seq.blocks[bi]) == 1, \
                    f"write to shared block {seq.blocks[bi]}"
            work.append((slot, seq.request.prompt[seq.filled:
                                                  seq.filled + chunk],
                         seq.filled))
            budget -= chunk
        return work

    def chunk_filled(self, slot: int, n_tokens: int):
        """Record a finished prefill chunk; newly FULL prompt blocks become
        visible to the prefix cache (their K/V is completely written, so a
        later admission may share them)."""
        seq = self.slots[slot]
        seq.filled += n_tokens
        self.prefill_tokens += n_tokens
        if self.prefix is None:
            return
        for i in range(seq.registered,
                       min(seq.filled // self.block_size,
                           len(seq.block_hashes))):
            self.prefix.insert(seq.block_hashes[i], seq.blocks[i])
            seq.registered = i + 1

    # -- decode bookkeeping -------------------------------------------------
    def start_decode(self, slot: int, first_token: int) -> bool:
        """Transition a fully-prefilled slot to decoding with the token its
        final chunk sampled.  Returns True if it retired immediately."""
        seq = self.slots[slot]
        assert seq.decoding and not seq.tokens
        seq.pos = len(seq.request.prompt)
        seq.tokens.append(first_token)
        return self._maybe_retire(slot)

    def decoding_slots(self) -> List[int]:
        """Slots whose prompt is fully prefilled and first token sampled."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.decoding and s.tokens]

    def ensure_blocks_through(self, slot: int, last_pos: int):
        """Materialise blocks so every position up to ``last_pos`` inclusive
        is writable by this row, drawing on the reservation made at
        admission (never fails).  ``last_pos == seq.pos`` is the plain
        decode case; speculative verification passes ``seq.pos + n_drafts``
        (clamped to the reservation's worst case by the caller, see
        serving/speculative.py)."""
        seq = self.slots[slot]
        bi = last_pos // self.block_size
        while len(seq.blocks) <= bi:
            seq.blocks.append(self._alloc_block())
            seq.fresh_blocks += 1
            seq.reserved -= 1
            self.total_reserved -= 1
            assert seq.reserved >= 0, "reservation underflow"
        for j in range(seq.pos // self.block_size, bi + 1):
            assert self.allocator.refcount(seq.blocks[j]) == 1, \
                f"decode write to shared block {seq.blocks[j]}"

    def ensure_decode_blocks(self):
        """Materialise the block each decoding row's next write lands in."""
        for slot in self.decoding_slots():
            self.ensure_blocks_through(slot, self.slots[slot].pos)

    def rollback_blocks(self, slot: int) -> int:
        """Free speculative tail blocks past the row's next write position.

        After a verify step accepted fewer drafts than were written, blocks
        whose every position is > ``seq.pos`` hold only rejected-token K/V
        that no future query can read before it is rewritten (the next
        verify writes from ``seq.pos`` contiguously).  Those blocks go back
        to the free list and their count returns to the row's reservation,
        so other admissions can use the memory immediately.  Only fresh
        decode blocks are ever in this tail: prompt blocks (including
        prefix-cache-registered ones) all sit at indices <= pos // bs.
        Returns the number of blocks freed."""
        seq = self.slots[slot]
        keep = seq.pos // self.block_size + 1
        freed = 0
        while len(seq.blocks) > keep:
            blk = seq.blocks.pop()
            assert self.allocator.refcount(blk) == 1, \
                f"speculative tail block {blk} is shared"
            assert self.prefix is None or \
                not self.prefix.contains_block(blk), \
                f"speculative tail block {blk} is prefix-registered"
            self.allocator.decref(blk)
            self.allocator.free(blk)
            seq.fresh_blocks -= 1
            seq.reserved += 1
            self.total_reserved += 1
            freed += 1
        return freed

    def observe(self, slot: int, token: int) -> bool:
        """Record one decoded token.  Returns True if the request retired."""
        seq = self.slots[slot]
        assert seq is not None and seq.decoding
        seq.pos += 1
        seq.tokens.append(token)
        return self._maybe_retire(slot)

    def _maybe_retire(self, slot: int) -> bool:
        seq = self.slots[slot]
        reason = None
        if self.eos_id is not None and seq.tokens[-1] == self.eos_id:
            reason = "eos"
        elif len(seq.tokens) >= seq.request.max_new_tokens:
            reason = "length"
        elif seq.pos + 1 >= self.s_max:
            reason = "cache_full"
        if reason is None:
            return False
        self.finished.append(FinishedRequest(
            rid=seq.request.rid, prompt=list(seq.request.prompt),
            tokens=list(seq.tokens), finish_reason=reason))
        self.request_stats[seq.request.rid] = dict(
            cached_tokens=seq.num_cached, fresh_blocks=seq.fresh_blocks)
        self.total_reserved -= seq.reserved
        for blk in seq.blocks:
            self._release_block(blk)
        self.slots[slot] = None
        return True

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def block_table_row(self, slot: int) -> List[int]:
        """The row's logical->physical block ids, in logical order (the
        device-side step right-pads this into its (B, max_blocks) table)."""
        return list(self.slots[slot].blocks)

    def live_blocks(self) -> int:
        """Blocks held by in-flight requests (evictable prefix-cache
        residents are reclaimable, so they don't count as in use)."""
        ev = self.prefix.num_evictable() if self.prefix is not None else 0
        return self.allocator.num_in_use() - ev

    def stats(self) -> Dict[str, float]:
        denom = self.prefix_hit_tokens + self.prefill_tokens
        return dict(
            prefix_hit_tokens=self.prefix_hit_tokens,
            prefill_tokens=self.prefill_tokens,
            prefix_hit_rate=self.prefix_hit_tokens / denom if denom else 0.0,
            blocks_in_use=self.live_blocks(),
            blocks_total=self.allocator.num_blocks,
            total_block_allocs=self.allocator.total_allocs - self._alloc_base,
            deferred_admissions=self.deferred_admissions,
        )

    def reset_stats(self):
        """Zero the counters (bench warmup); block state is untouched."""
        self.prefix_hit_tokens = 0
        self.prefill_tokens = 0
        self.deferred_admissions = 0
        self._alloc_base = self.allocator.total_allocs
        self.request_stats.clear()


class PagedServingEngine(_ServingEngineBase):
    """Drives ``PagedScheduler`` against the jitted block-pool steps
    (``engine.build_paged_steps``).

    One ``step()`` = admissions (host-only block accounting) + up to
    ``max_prefill_tokens`` prompt tokens of chunked prefill + one batched
    decode of every in-flight row through its block table.  Emits tokens
    bit-identical to ``ContinuousServingEngine`` (tests/test_paged.py) while
    admitting on block availability rather than whole-slot reservations.

    Supports decoder-only full-attention families (ring/MLA/recurrent state
    keeps the ragged engine) at TP >= 1; the pool has no batch axis, so
    data-parallel sharding of slots is not available on this path.
    """

    def __init__(self, cfg, params, *, batch_slots: int, s_max: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 pcfg=None, mesh=None, eos_id: Optional[int] = None,
                 rng_seed: int = 0, max_prefill_tokens: int = 128,
                 prefill_bucket_min: int = 16, prefix_caching: bool = True,
                 use_pallas: Optional[bool] = None, kv_quant: str = "fp",
                 oversubscribe: float = 1.0, swap_blocks: int = 0,
                 comm_overlap: bool = False, comm_quant: bool = False,
                 comm_chunks: int = 4, comm_fuse_norm: bool = False,
                 tuned: bool = True):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import ParallelConfig
        from repro.models import transformer as _tfm
        from repro.parallel import compat
        from repro.serving import engine as engine_mod
        from repro.serving.kv_cache import BlockAllocator, PrefixCache

        if cfg.encoder_layers or cfg.family == "vlm":
            raise NotImplementedError(
                "paged serving targets decoder-only token models")
        unsupported = {
            sub for kind in _tfm.effective_kinds(cfg)
            for sub in _tfm.subblocks_of(kind)
            if sub not in ("attn", "mlp", "moe", "dense_mlp")}
        if unsupported:
            raise NotImplementedError(
                f"paged serving supports full-attention stacks only "
                f"(found {sorted(unsupported)}); use the ragged engine")
        pcfg = pcfg if pcfg is not None else ParallelConfig()
        if max(1, pcfg.dp) * max(1, pcfg.pods) > 1:
            raise NotImplementedError(
                "paged serving shards over TP only (the block pool has no "
                "batch axis for DP)")
        if use_pallas is not None and use_pallas != cfg.use_pallas:
            # route the paged attention read through the block-table-native
            # Pallas kernel (or force the gather oracle); token streams are
            # bit-identical either way (tests/test_paged_kernel.py)
            cfg = cfg.replace(use_pallas=use_pallas)

        self._jnp, self._np = jnp, np
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.s_max = s_max
        self.block_size = block_size
        self.max_blocks = -(-s_max // block_size)
        self.num_blocks = num_blocks if num_blocks is not None else \
            batch_slots * self.max_blocks
        self.prefill_bucket_min = prefill_bucket_min
        self.kv_quant = kv_quant

        self.allocator = BlockAllocator(self.num_blocks, block_size)
        self.prefix = PrefixCache() if prefix_caching else None
        if oversubscribe != 1.0 or swap_blocks > 0:
            # KV memory tiers (serving/memory.py; DESIGN.md §KV memory
            # tiers): admission may oversubscribe the pool; on allocation
            # pressure the engine swaps out the lowest-priority decoding
            # row and resumes it verbatim when blocks free up
            from repro.serving.memory import (PreemptivePagedScheduler,
                                              SwapPool)
            self.scheduler = PreemptivePagedScheduler(
                batch_slots, s_max, self.allocator,
                prefix_cache=self.prefix, eos_id=eos_id,
                max_prefill_tokens=max_prefill_tokens,
                oversubscribe=oversubscribe)
            self.swap = SwapPool(capacity_blocks=swap_blocks)
        else:
            self.scheduler = PagedScheduler(
                batch_slots, s_max, self.allocator, prefix_cache=self.prefix,
                eos_id=eos_id, max_prefill_tokens=max_prefill_tokens)
            self.swap = None

        # TP comm mode for the jitted steps (parallel/overlap.py):
        # --comm-quant implies the ring (the int8 wire IS a ring format),
        # so it wins over plain --comm-overlap; --comm-fuse-norm implies
        # the int8 wire (the deferred images ARE its format) and
        # additionally defers the dequant-sum into the next sub-block's
        # RMSNorm — a ladder-only schedule, since only the ladder carries
        # an unconsumed pending across a sub-block (core/residual.py).
        from repro.configs.base import ResidualMode
        from repro.parallel.collectives import CommConfig
        if comm_fuse_norm and cfg.residual_mode != ResidualMode.LADDER:
            raise NotImplementedError(
                "comm_fuse_norm rides the ladder topology's deferred "
                f"pending; residual_mode={cfg.residual_mode} keeps the "
                "AllReduce on the critical path with nothing to defer")
        self.comm = CommConfig(
            mode=("compressed" if comm_quant or comm_fuse_norm
                  else "overlap" if comm_overlap else "sync"),
            chunks=comm_chunks, fuse_norm=comm_fuse_norm)
        steps = engine_mod.build_paged_steps(cfg, pcfg,
                                             batch_slots=batch_slots,
                                             rng_seed=rng_seed,
                                             use_pallas=use_pallas,
                                             comm=self.comm,
                                             tuned=tuned,
                                             max_blocks=self.max_blocks)
        self.caches, cache_specs = engine_mod.build_caches(
            cfg, batch_slots, s_max, pcfg, for_decode=False, paged=True,
            num_blocks=self.num_blocks, block_size=block_size,
            kv_quant=kv_quant)

        if mesh is not None and pcfg.world > 1:
            ps = steps["pspecs"]
            r = P()                                # host vectors: replicated
            prefill_chunk = compat.shard_map(
                steps["prefill_chunk"], mesh,
                (ps, cache_specs, r, r, r, r, r, r, r, r),
                (cache_specs, r))
            decode = compat.shard_map(
                steps["decode"], mesh,
                (ps, cache_specs, r, r, r, r, r, r, r, r),
                (cache_specs, r))
            decode_greedy = compat.shard_map(
                steps["decode_greedy"], mesh,
                (ps, cache_specs, r, r, r, r), (cache_specs, r))
            verify = compat.shard_map(
                steps["verify"], mesh,
                (ps, cache_specs, r, r, r, r, r, r, r, r, r),
                (cache_specs, r))
            verify_greedy = compat.shard_map(
                steps["verify_greedy"], mesh,
                (ps, cache_specs, r, r, r, r, r), (cache_specs, r))
            self._mesh_ctx = lambda: compat.set_mesh(mesh)
        else:
            prefill_chunk = steps["prefill_chunk"]
            decode, decode_greedy = steps["decode"], steps["decode_greedy"]
            verify, verify_greedy = steps["verify"], steps["verify_greedy"]
            import contextlib
            self._mesh_ctx = contextlib.nullcontext
        self._prefill_chunk = jax.jit(prefill_chunk, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._decode_greedy = jax.jit(decode_greedy, donate_argnums=(1,))
        # speculative verification (jit is lazy: no compile unless used)
        self._verify = jax.jit(verify, donate_argnums=(1,))
        self._verify_greedy = jax.jit(verify_greedy, donate_argnums=(1,))

        self._init_host_vectors(batch_slots)
        self._bt = np.zeros((batch_slots, self.max_blocks), np.int32)
        # block-utilization time series (bench reporting)
        self._util_sum = 0.0
        self._util_peak = 0.0
        self._util_steps = 0

    # -- public API ---------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Scheduler counters (prefix hits, allocs, deferrals) plus the
        engine's block-utilization time series."""
        s = self.scheduler.stats()
        s["block_util_mean"] = self._util_sum / max(self._util_steps, 1)
        s["block_util_peak"] = self._util_peak
        if self.swap is not None:
            s["swapped_out_blocks"] = self.swap.total_swapped_out
            s["swapped_in_blocks"] = self.swap.total_swapped_in
            s["swap_peak_blocks"] = self.swap.peak_blocks
        return s

    def reset_stats(self):
        """Zero all counters (bench warmup); cache/block state untouched."""
        self.scheduler.reset_stats()
        self._util_sum = self._util_peak = 0.0
        self._util_steps = 0
        if self.swap is not None:
            self.swap.total_swapped_out = 0
            self.swap.total_swapped_in = 0
            self.swap.peak_blocks = self.swap.num_held()

    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration.  Returns (rid, token) events emitted."""
        events: List[Tuple[int, int]] = []

        with self._mesh_ctx():
            self._resume_preempted()    # swapped-out rows are oldest: first
            self.scheduler.admissions()
            for slot, chunk, start in self.scheduler.prefill_work():
                req = self.scheduler.slots[slot].request
                tok = self._run_chunk(slot, req, chunk, start)
                self.scheduler.chunk_filled(slot, len(chunk))
                if start + len(chunk) == len(req.prompt):   # final chunk
                    events.append((req.rid, tok))
                    if not self.scheduler.start_decode(slot, tok):
                        self._start_decode_slot(slot, req, tok)

            live = self.scheduler.decoding_slots()
            if live:
                events.extend(self._decode_phase(live))

        util = self.scheduler.live_blocks() / self.allocator.num_blocks
        self._util_sum += util
        self._util_peak = max(self._util_peak, util)
        self._util_steps += 1
        return events

    def _decode_phase(self, live: List[int]) -> List[Tuple[int, int]]:
        """One batched decode of the in-flight rows (the speculative engine
        overrides this with a draft-and-verify round)."""
        for slot in live:
            if self.scheduler.slots[slot] is None:
                continue                # preempted as an earlier victim
            self._ensure_through(slot, self.scheduler.slots[slot].pos)
        # a later ensure may have preempted an earlier row: keep survivors
        live = [s for s in live if self.scheduler.slots[s] is not None]
        if not live:
            return []
        for slot in live:
            self._fill_bt_row(slot)
        w = self._bt_width(live)
        return self._decode_step(live, (self._jnp.asarray(self._bt[:, :w]),))

    # -- KV memory tiers (preemption + swap; DESIGN.md §KV memory tiers) ----
    def _ensure_through(self, slot: int, last_pos: int) -> bool:
        """``ensure_blocks_through`` with preemption-on-pressure: when the
        physical pool runs dry (only possible under the oversubscribing
        scheduler), the lowest-priority decoding row is swapped out and the
        allocation retried.  Returns False iff `slot` itself was the victim
        (the caller drops it from this step's batch)."""
        from repro.serving.kv_cache import BlockAllocationError
        while True:
            try:
                self.scheduler.ensure_blocks_through(slot, last_pos)
                return True
            except BlockAllocationError:
                victim = getattr(self.scheduler, "pick_victim",
                                 lambda: None)()
                if victim is None or self.swap is None:
                    raise
                self._swap_out(victim)
                if victim == slot:
                    return False

    def _swap_out(self, slot: int):
        """Preempt `slot`: copy its blocks' contents to the host swap tier,
        then release the blocks/slot/reservation.  Raw pool bytes move —
        bit-identical for fp pools, never re-quantized for int8."""
        from repro.serving import memory
        seq = self.scheduler.slots[slot]
        payloads = memory.extract_blocks(self.caches, seq.blocks,
                                         self.block_size)
        self.swap.put_seq(seq.admit_id, payloads)
        self.scheduler.preempt(slot)
        self._active[slot] = False

    def _resume_preempted(self):
        """Swap preempted rows back in (FIFO) while slots and blocks allow;
        each resumes decoding from exactly its saved position."""
        if self.swap is None:
            return
        from repro.serving import memory
        while True:
            r = self.scheduler.resume_ready()
            if r is None:
                break
            slot, seq = r
            payloads = self.swap.take_seq(seq.admit_id, len(seq.blocks))
            self.caches = memory.insert_blocks(self.caches, seq.blocks,
                                               payloads, self.block_size)
            self._resume_decode_slot(slot, seq)

    def _resume_decode_slot(self, slot: int, seq) -> None:
        """Re-arm the host decode vectors for a resumed row (the
        speculative engine additionally re-prefills its drafter)."""
        sp = seq.request.sampling
        self._tokens[slot] = seq.tokens[-1]
        self._pos[slot] = seq.pos
        self._active[slot] = True
        self._temp[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._seeds[slot] = sp.seed

    # -- internals ----------------------------------------------------------
    def _fill_bt_row(self, slot: int):
        row = self.scheduler.block_table_row(slot)
        self._bt[slot, :len(row)] = row
        self._bt[slot, len(row):] = 0

    def _bt_width(self, slots: List[int]) -> int:
        """Power-of-two bucket of the max in-use block count among `slots`.

        The steps accept any table width covering the rows' blocks
        (engine.build_paged_steps), so passing the bucketed live width
        instead of the static ``max_blocks`` makes the gather oracle's
        traffic — and the Pallas kernel's grid — track actual pool
        occupancy, at the cost of O(log max_blocks) jit variants (same
        trade as the prefill length buckets)."""
        used = max(len(self.scheduler.slots[s].blocks) for s in slots)
        return min(_bucket(used, 1), self.max_blocks)

    def _run_chunk(self, slot: int, req: Request, chunk: List[int],
                   start: int) -> int:
        jnp, np = self._jnp, self._np
        sp = req.sampling
        c = len(chunk)
        lb = _bucket(c, self.prefill_bucket_min)
        toks = np.zeros((1, lb), np.int32)
        toks[0, :c] = chunk
        self._fill_bt_row(slot)
        w = self._bt_width([slot])
        self.caches, tok = self._prefill_chunk(
            self.params, self.caches, jnp.asarray(toks),
            jnp.asarray(start, jnp.int32), jnp.asarray(c, jnp.int32),
            jnp.asarray(self._bt[slot:slot + 1, :w]),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray([sp.seed], jnp.int32))
        return int(tok[0])


# ---------------------------------------------------------------------------
# synthetic traffic (benchmarks)
# ---------------------------------------------------------------------------

def poisson_trace(n_requests: int, rate: float, seed: int, *,
                  prompt_lens=(8, 96), max_new=(4, 48),
                  vocab: int = 1024, sampling: Optional[Callable[[int],
                                                     SamplingParams]] = None):
    """Synthetic Poisson arrival trace: exponential inter-arrival times at
    `rate` req/s, uniform prompt lengths and generation budgets."""
    import numpy as np
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        lp = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab, size=lp).tolist(),
            max_new_tokens=int(rng.integers(max_new[0], max_new[1] + 1)),
            sampling=sampling(rid) if sampling else SamplingParams(),
            arrival=t))
    return out


def serve_trace(engine: "ContinuousServingEngine", trace: List[Request],
                *, now: Optional[Callable[[], float]] = None):
    """Replay an arrival trace against a live engine, recording per-token
    wall-clock timestamps.  Returns (finished, per-request token times)."""
    clock = now or time.monotonic
    t0 = clock()
    pending = sorted(trace, key=lambda r: r.arrival)
    tok_times: Dict[int, List[float]] = {r.rid: [] for r in trace}
    i = 0
    while i < len(pending) or engine.has_work():
        t = clock() - t0
        while i < len(pending) and pending[i].arrival <= t:
            engine.submit(pending[i])
            i += 1
        if not engine.has_work():
            # idle: sleep until the next arrival (keeps TTFT honest)
            dt = pending[i].arrival - (clock() - t0)
            if dt > 0:
                time.sleep(dt)
            continue
        for rid, _tok in engine.step():
            tok_times[rid].append(clock() - t0)
    finished = {f.rid: f for f in engine.scheduler.finished}
    return finished, tok_times
