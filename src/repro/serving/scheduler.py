"""Continuous-batching request scheduler and serving engine.

The paper's wall-clock win is a per-step property; this module is what makes
it matter under real traffic: a fixed pool of ``batch_slots`` decode slots
stays full by admitting variable-length requests as they arrive, interleaving
prefill of new requests with decode of in-flight ones, retiring sequences on
EOS or length cap, and refilling freed slots (DESIGN.md §Serving).

Split of responsibilities:

* ``Scheduler``  — pure host-side bookkeeping (FIFO admission queue, slot
  lifecycle, retirement rules).  No jax; unit-testable in microseconds.
* ``ContinuousServingEngine`` — owns the device state (ragged caches, jitted
  prefill/decode from ``engine.build_continuous_steps``) and drives the
  scheduler.  One jitted decode graph serves a mixed-age batch under any
  ``ResidualMode`` and TP/DP sharding.

Determinism contract: a request's output tokens depend only on (prompt,
sampling params, seed) — never on which slot it lands in or what else is in
flight — because attention masks key on per-row ``slot_pos`` and sampling
keys fold (seed, absolute position).  ``tests/test_scheduler.py`` asserts
bit-identity between continuous and isolated decoding.  (MoE models with
finite expert capacity are the documented exception: routing competes across
the batch, so outputs can differ at capacity.)
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.  temperature <= sampler.GREEDY_EPS
    decodes greedily; top_k <= 0 and top_p >= 1 disable the filters."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival: float = 0.0          # bench bookkeeping (seconds or step index)


@dataclass
class _Slot:
    request: Request
    pos: int                      # absolute position of the LAST sampled token
    tokens: List[int]             # generated so far (first token from prefill)


@dataclass
class FinishedRequest:
    rid: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str            # "eos" | "length" | "cache_full"


class Scheduler:
    """FIFO admission into a fixed slot pool, with per-slot retirement.

    The scheduler never touches arrays: callers report sampled tokens via
    ``start``/``observe`` and receive retirement decisions back.
    """

    def __init__(self, n_slots: int, s_max: int, eos_id: Optional[int] = None,
                 max_prefills_per_step: int = 1):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.s_max = s_max
        self.eos_id = eos_id
        self.max_prefills_per_step = max_prefills_per_step
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.finished: List[FinishedRequest] = []

    # -- submission ---------------------------------------------------------
    def submit(self, request: Request):
        if not request.prompt:
            raise ValueError(f"request {request.rid}: empty prompt")
        if len(request.prompt) > self.s_max - 1:
            raise ValueError(
                f"request {request.rid}: prompt {len(request.prompt)} "
                f"does not fit s_max={self.s_max} (need prompt <= s_max-1)")
        self.queue.append(request)

    # -- admission ----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admissions(self) -> List[Tuple[int, Request]]:
        """Pick (slot, request) pairs to prefill this step: FIFO order, at
        most ``max_prefills_per_step`` (so one queue burst cannot starve
        in-flight decodes — prefill interleaves with decode)."""
        out = []
        for slot in self.free_slots()[:self.max_prefills_per_step]:
            if not self.queue:
                break
            out.append((slot, self.queue.popleft()))
        return out

    def start(self, slot: int, request: Request, first_token: int) -> bool:
        """Mark `slot` active after its prefill sampled `first_token`.
        Returns True if the request retired immediately."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        self.slots[slot] = _Slot(request=request, pos=len(request.prompt),
                                 tokens=[first_token])
        return self._maybe_retire(slot)

    # -- decode bookkeeping -------------------------------------------------
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def observe(self, slot: int, token: int) -> bool:
        """Record one decoded token for an active slot.  Returns True if the
        request retired (slot is freed)."""
        st = self.slots[slot]
        assert st is not None, f"slot {slot} inactive"
        st.pos += 1
        st.tokens.append(token)
        return self._maybe_retire(slot)

    def _maybe_retire(self, slot: int) -> bool:
        st = self.slots[slot]
        reason = None
        if self.eos_id is not None and st.tokens[-1] == self.eos_id:
            reason = "eos"
        elif len(st.tokens) >= st.request.max_new_tokens:
            reason = "length"
        elif st.pos + 1 >= self.s_max:
            # the NEXT decode would write K/V past the last cache slot
            reason = "cache_full"
        if reason is None:
            return False
        self.finished.append(FinishedRequest(
            rid=st.request.rid, prompt=list(st.request.prompt),
            tokens=list(st.tokens), finish_reason=reason))
        self.slots[slot] = None
        return True

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)


# ---------------------------------------------------------------------------
# device-side engine
# ---------------------------------------------------------------------------

def _bucket(n: int, lo: int = 16) -> int:
    """Round the prompt length up to a power-of-two bucket, bounding jit
    recompiles to O(log s_max) prefill shapes."""
    b = lo
    while b < n:
        b *= 2
    return b


class ContinuousServingEngine:
    """Drives ``Scheduler`` against the jitted ragged-cache steps.

    One ``step()`` = up to ``max_prefills_per_step`` prefills (admitting new
    requests into freed slots) + one batched decode of every in-flight slot.
    """

    def __init__(self, cfg, params, *, batch_slots: int, s_max: int,
                 pcfg=None, mesh=None, eos_id: Optional[int] = None,
                 rng_seed: int = 0, max_prefills_per_step: int = 1,
                 prefill_bucket_min: int = 16):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import ParallelConfig
        from repro.parallel import compat
        from repro.serving import engine as engine_mod

        if cfg.encoder_layers or cfg.family == "vlm":
            raise NotImplementedError(
                "continuous batching currently targets decoder-only token "
                "models (enc-dec / VLM requests carry per-request frontend "
                "state the slot pool does not manage yet)")

        self._jnp, self._np = jnp, np
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.s_max = s_max
        self.prefill_bucket_min = prefill_bucket_min
        # Recurrent sub-blocks (mamba/rwkv) consume every input token into
        # their state regardless of position masking, so right-padding the
        # prompt would corrupt the state the decode steps start from.  Those
        # families prefill at EXACT length (one jit compile per distinct
        # prompt length) instead of power-of-two buckets.
        from repro.models import transformer as _tfm
        self._exact_prefill = any(
            sub in ("mamba", "rwkv_tmix", "rwkv_cmix")
            for kind in _tfm.effective_kinds(cfg)
            for sub in _tfm.subblocks_of(kind))
        pcfg = pcfg if pcfg is not None else ParallelConfig()
        self.scheduler = Scheduler(batch_slots, s_max, eos_id=eos_id,
                                   max_prefills_per_step=max_prefills_per_step)

        steps = engine_mod.build_continuous_steps(
            cfg, pcfg, batch_slots=batch_slots, rng_seed=rng_seed)
        self.caches, cache_specs = engine_mod.build_caches(
            cfg, batch_slots, s_max, pcfg, for_decode=False, ragged=True)

        if mesh is not None and pcfg.world > 1:
            vs, ps = steps["vec_spec"], steps["pspecs"]
            scalar = P()
            prefill = compat.shard_map(
                steps["prefill"], mesh,
                (ps, cache_specs, scalar, scalar, scalar, scalar, scalar,
                 scalar, scalar),
                (cache_specs, scalar))
            decode = compat.shard_map(
                steps["decode"], mesh,
                (ps, cache_specs, vs, vs, vs, vs, vs, vs, vs),
                (cache_specs, vs))
            decode_greedy = compat.shard_map(
                steps["decode_greedy"], mesh,
                (ps, cache_specs, vs, vs, vs), (cache_specs, vs))
            self._mesh_ctx = lambda: compat.set_mesh(mesh)
        else:
            prefill, decode = steps["prefill"], steps["decode"]
            decode_greedy = steps["decode_greedy"]
            import contextlib
            self._mesh_ctx = contextlib.nullcontext
        self._prefill = jax.jit(prefill, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._decode_greedy = jax.jit(decode_greedy, donate_argnums=(1,))

        # host-side per-slot vectors fed to the decode step
        z = lambda dt, fill=0: np.full((batch_slots,), fill, dt)
        self._tokens = z(np.int32)
        self._pos = z(np.int32)
        self._active = z(bool, False)
        self._temp = z(np.float32, 0.0)
        self._top_k = z(np.int32)
        self._top_p = z(np.float32, 1.0)
        self._seeds = z(np.int32)

    # -- public API ---------------------------------------------------------
    def submit(self, request: Request):
        self.scheduler.submit(request)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration.  Returns (rid, token) events emitted."""
        jnp, np = self._jnp, self._np
        events: List[Tuple[int, int]] = []

        with self._mesh_ctx():
            for slot, req in self.scheduler.admissions():
                tok = self._run_prefill(slot, req)
                events.append((req.rid, tok))
                if not self.scheduler.start(slot, req, tok):
                    sp = req.sampling
                    self._tokens[slot] = tok
                    self._pos[slot] = len(req.prompt)
                    self._active[slot] = True
                    self._temp[slot] = sp.temperature
                    self._top_k[slot] = sp.top_k
                    self._top_p[slot] = sp.top_p
                    self._seeds[slot] = sp.seed

            live = self.scheduler.active_slots()
            if live:
                from repro.serving.sampler import GREEDY_EPS
                if all(self._temp[s] <= GREEDY_EPS for s in live):
                    # hot default: every in-flight request decodes greedily
                    self.caches, toks = self._decode_greedy(
                        self.params, self.caches,
                        jnp.asarray(self._tokens), jnp.asarray(self._pos),
                        jnp.asarray(self._active))
                else:
                    self.caches, toks = self._decode(
                        self.params, self.caches,
                        jnp.asarray(self._tokens), jnp.asarray(self._pos),
                        jnp.asarray(self._active), jnp.asarray(self._temp),
                        jnp.asarray(self._top_k), jnp.asarray(self._top_p),
                        jnp.asarray(self._seeds))
                toks = np.asarray(toks)
                for slot in live:
                    tok = int(toks[slot])
                    rid = self.scheduler.slots[slot].request.rid
                    events.append((rid, tok))
                    if self.scheduler.observe(slot, tok):
                        self._active[slot] = False
                    else:
                        self._tokens[slot] = tok
                        self._pos[slot] += 1
        return events

    def run(self) -> Dict[int, FinishedRequest]:
        """Drain the queue completely; returns rid -> FinishedRequest."""
        while self.has_work():
            self.step()
        return {f.rid: f for f in self.scheduler.finished}

    # -- internals ----------------------------------------------------------
    def _run_prefill(self, slot: int, req: Request) -> int:
        jnp, np = self._jnp, self._np
        sp = req.sampling
        length = len(req.prompt)
        lb = length if self._exact_prefill else \
            _bucket(length, self.prefill_bucket_min)
        toks = np.zeros((1, lb), np.int32)
        toks[0, :length] = req.prompt
        self.caches, tok = self._prefill(
            self.params, self.caches, jnp.asarray(toks),
            jnp.asarray(length, jnp.int32), jnp.asarray(slot, jnp.int32),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray([sp.seed], jnp.int32))
        return int(tok[0])


# ---------------------------------------------------------------------------
# synthetic traffic (benchmarks)
# ---------------------------------------------------------------------------

def poisson_trace(n_requests: int, rate: float, seed: int, *,
                  prompt_lens=(8, 96), max_new=(4, 48),
                  vocab: int = 1024, sampling: Optional[Callable[[int],
                                                     SamplingParams]] = None):
    """Synthetic Poisson arrival trace: exponential inter-arrival times at
    `rate` req/s, uniform prompt lengths and generation budgets."""
    import numpy as np
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        lp = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab, size=lp).tolist(),
            max_new_tokens=int(rng.integers(max_new[0], max_new[1] + 1)),
            sampling=sampling(rid) if sampling else SamplingParams(),
            arrival=t))
    return out


def serve_trace(engine: "ContinuousServingEngine", trace: List[Request],
                *, now: Optional[Callable[[], float]] = None):
    """Replay an arrival trace against a live engine, recording per-token
    wall-clock timestamps.  Returns (finished, per-request token times)."""
    clock = now or time.monotonic
    t0 = clock()
    pending = sorted(trace, key=lambda r: r.arrival)
    tok_times: Dict[int, List[float]] = {r.rid: [] for r in trace}
    i = 0
    while i < len(pending) or engine.has_work():
        t = clock() - t0
        while i < len(pending) and pending[i].arrival <= t:
            engine.submit(pending[i])
            i += 1
        if not engine.has_work():
            # idle: sleep until the next arrival (keeps TTFT honest)
            dt = pending[i].arrival - (clock() - t0)
            if dt > 0:
                time.sleep(dt)
            continue
        for rid, _tok in engine.step():
            tok_times[rid].append(clock() - t0)
    finished = {f.rid: f for f in engine.scheduler.finished}
    return finished, tok_times
