"""Speculative decoding on the paged serving engine.

Decode is one-token-per-communication-round; speculation multiplies the
work available per round.  Each engine step proposes K draft tokens per
in-flight request (from a cheap drafter), then runs ONE batched verify
forward through the paged attention path — K+1 query tokens per row — and
accepts the longest draft prefix that matches what the target model itself
would have sampled.  Every accepted draft saves a full decode forward (and,
under TP, its AllReduce rounds), which is exactly the regime where the
ladder residual's communication overlap compounds (DESIGN.md §Speculative
decoding).

Pieces:

* ``NgramDrafter``       — self-speculation via prompt-lookup: propose the
  continuation of the most recent earlier occurrence of the context's
  suffix n-gram.  Pure host, zero extra forwards; shines on repetitive or
  shared-prefix traffic.
* ``DraftModelDrafter``  — a small config-selected draft transformer
  sharing the target's vocab, decoding greedily into its own ragged cache
  (one cheap forward per draft token, replicated — never TP-sharded).
* ``SpeculativePagedEngine`` — ``PagedServingEngine`` with the decode
  phase replaced by draft → batched verify → accept-walk → KV rollback.

Distribution-equivalence contract (the reason this is testable as bit
equality rather than statistics): the serving sampler is deterministic
given (seed, absolute position) — greedy rows take argmax, sampled rows
take argmax(filtered logits + Gumbel(key(seed, pos))).  The verify step
samples the target token for every position with exactly those keys, so
"accept draft iff draft == target's token" is the standard rejection-
sampling rule instantiated with coupled randomness: acceptance probability
is min(1, p/q) under the shared noise, and the emitted stream is not just
distribution-identical but BIT-identical to non-speculative decode —
for greedy and seeded sampling, any drafter, any ResidualMode
(tests/test_speculative.py; TP=2 group in tests/distributed_impl.py).
The general-distribution stochastic rule (accept w.p. min(1, p/q), resample
the residual) lives in ``sampler.rejection_sample`` with its own empirical
unit test.

KV rollback invariant: a verify step writes K/V for all K+1 fed tokens at
positions pos..pos+K through the block table.  On partial acceptance the
cache holds stale entries past the new position, but no query can ever
read them before they are rewritten: reads are masked to slot <= query
position, and writes advance contiguously from the commit point — the
same overwrite-before-read argument that makes chunked prefill exact.
Only the HOST-side block accounting needs repair: tail blocks holding
nothing but rejected-token positions are freed back to the pool
(``PagedScheduler.rollback_blocks``) and their count returns to the row's
reservation, so speculation never shrinks the admission budget.
"""

from __future__ import annotations

from typing import Dict, List

from repro.serving.scheduler import PagedServingEngine, Request, _bucket


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------

def derive_draft_cfg(cfg, n_layers: int):
    """The standard config-derived draft: the target's exact shape with
    fewer layers.  ``reduced()`` resets d_model/vocab/heads to its tiny
    defaults unless re-passed, so every shape field is pinned back to the
    target's — in particular the vocab, which ``DraftModelDrafter``
    requires to match."""
    return cfg.reduced(
        n_layers=n_layers, d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff,
        vocab_size=cfg.vocab_size)


class NgramDrafter:
    """Prompt-lookup (self-speculation) drafting.

    ``propose`` scans each row's full context (prompt + generated tokens)
    for the most recent earlier occurrence of its suffix n-gram, longest n
    first, and proposes the tokens that followed it.  No model, no state
    beyond the scheduler's own context — misses cost nothing (the verify
    step degenerates to plain decode).
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def prefill(self, slot: int, prompt: List[int], first_token: int):
        """No per-slot state: context is re-read from the scheduler."""

    def lookup(self, ctx: List[int], k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``ctx``, or [] on a miss."""
        n_hi = min(self.max_ngram, len(ctx) - 1)
        for n in range(n_hi, self.min_ngram - 1, -1):
            suffix = ctx[len(ctx) - n:]
            for j in range(len(ctx) - n - 1, -1, -1):
                if ctx[j:j + n] == suffix:
                    return ctx[j + n:j + n + k]
        return []

    def propose(self, live: List[int], contexts: Dict[int, List[int]],
                budgets: Dict[int, int]) -> Dict[int, List[int]]:
        """slot -> up to budgets[slot] draft tokens (possibly []) for each
        live slot; contexts[slot] is the row's prompt + generated tokens."""
        return {s: self.lookup(contexts[s], budgets[s]) if budgets[s] > 0
                else [] for s in live}


class DraftModelDrafter:
    """Draft-model proposals from a small transformer sharing the vocab.

    The draft decodes greedily (the standard choice: proposals only affect
    the accept rate, never output correctness) into its own ragged cache,
    one slot per engine slot.  Per engine step it catches up on the tokens
    the target committed since last round — overwriting any stale
    speculative K/V, which is safe by the same overwrite-before-read
    argument as the target cache — then rolls K single-token forwards to
    propose.  The draft always runs replicated (no TP/DP): it is small by
    construction and its outputs are only proposals.

    Per-slot draft state is ``_dpos[slot]``: how many committed context
    tokens the draft cache has consumed (its K/V covers positions
    ``0.._dpos-1``).
    """

    def __init__(self, cfg, draft_cfg, draft_params, *, batch_slots: int,
                 s_max: int, spec_k: int, rng_seed: int = 0,
                 prefill_bucket_min: int = 16):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.configs import ParallelConfig
        from repro.models import transformer as _tfm
        from repro.serving import engine as engine_mod

        if draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft model must share the target vocab "
                f"({draft_cfg.vocab_size} != {cfg.vocab_size})")
        if draft_cfg.encoder_layers or draft_cfg.family == "vlm":
            raise NotImplementedError(
                "draft models must be decoder-only token models")

        self._jnp, self._np = jnp, np
        self.draft_cfg = draft_cfg
        self.params = draft_params
        self.batch_slots = batch_slots
        self.prefill_bucket_min = prefill_bucket_min
        self._exact_prefill = any(
            sub in ("mamba", "rwkv_tmix", "rwkv_cmix")
            for kind in _tfm.effective_kinds(draft_cfg)
            for sub in _tfm.subblocks_of(kind))

        steps = engine_mod.build_continuous_steps(
            draft_cfg, ParallelConfig(), batch_slots=batch_slots,
            rng_seed=rng_seed)
        self._prefill = jax.jit(steps["prefill"], donate_argnums=(1,))
        self._decode_greedy = jax.jit(steps["decode_greedy"],
                                      donate_argnums=(1,))
        # draft writes run ahead of the target by up to spec_k positions
        self.caches, _ = engine_mod.build_caches(
            draft_cfg, batch_slots, s_max + spec_k + 1, ParallelConfig(),
            for_decode=False, ragged=True)
        self._dpos = np.zeros((batch_slots,), np.int64)

    def prefill(self, slot: int, prompt: List[int], first_token: int):
        """Prefill the draft cache for a newly-decoding engine slot (resets
        any stale slot state inside the jitted prefill)."""
        jnp, np = self._jnp, self._np
        lp = len(prompt)
        lb = lp if self._exact_prefill else \
            _bucket(lp, self.prefill_bucket_min)
        toks = np.zeros((1, lb), np.int32)
        toks[0, :lp] = prompt
        self.caches, _ = self._prefill(
            self.params, self.caches, jnp.asarray(toks),
            jnp.asarray(lp, jnp.int32), jnp.asarray(slot, jnp.int32),
            jnp.asarray([0.0], jnp.float32), jnp.asarray([0], jnp.int32),
            jnp.asarray([1.0], jnp.float32), jnp.asarray([0], jnp.int32))
        self._dpos[slot] = lp

    def _masked_decode(self, toks, pos, active):
        jnp = self._jnp
        self.caches, out = self._decode_greedy(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(active))
        return self._np.asarray(out)

    def propose(self, live: List[int], contexts: Dict[int, List[int]],
                budgets: Dict[int, int]) -> Dict[int, List[int]]:
        """slot -> up to budgets[slot] greedy draft tokens.  Runs masked
        (B, 1) draft decodes: first the catch-up rounds (committed tokens
        the draft cache has not consumed), then one roll per draft token."""
        np = self._np
        toks = np.zeros((self.batch_slots,), np.int32)
        pos = np.zeros((self.batch_slots,), np.int32)
        active = np.zeros((self.batch_slots,), bool)

        # catch-up: consume committed tokens up to (not incl.) the last one
        while True:
            active[:] = False
            for s in live:
                ctx = contexts[s]
                if self._dpos[s] < len(ctx) - 1:
                    toks[s] = ctx[self._dpos[s]]
                    pos[s] = self._dpos[s]
                    active[s] = True
            if not active.any():
                break
            self._masked_decode(toks, pos, active)
            for s in live:
                if active[s]:
                    self._dpos[s] += 1

        # proposal rolls: round 0 feeds the committed last token (so the
        # draft cache commits it — dpos advances), later rounds feed the
        # draft's own previous proposal
        drafts: Dict[int, List[int]] = {s: [] for s in live}
        cur = {s: contexts[s][-1] for s in live}
        for j in range(max((budgets[s] for s in live), default=0)):
            active[:] = False
            for s in live:
                if budgets[s] > j:
                    toks[s] = cur[s]
                    pos[s] = len(contexts[s]) - 1 + j
                    active[s] = True
            if not active.any():
                break
            out = self._masked_decode(toks, pos, active)
            for s in live:
                if active[s]:
                    if j == 0:
                        self._dpos[s] = len(contexts[s])
                    cur[s] = int(out[s])
                    drafts[s].append(cur[s])
        return drafts


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class SpeculativePagedEngine(PagedServingEngine):
    """Paged serving with draft-and-verify decode.

    One ``step()`` = admissions + chunked prefill (inherited) + ONE verify
    forward over every in-flight row: row b feeds its last sampled token
    plus up to ``spec_k`` draft tokens at positions ``pos..pos+k_b``, and
    the device returns the token the target samples for every one of those
    positions.  The host emits the longest prefix where draft and target
    agree plus the target's first disagreeing (or bonus) token — between 1
    and ``k_b + 1`` tokens per row per forward — then frees speculative
    tail blocks (``rollback_blocks``).

    Per-row draft budgets are clamped so speculative writes never exceed
    the admission reservation: ``k_b = min(spec_k, remaining_tokens - 1,
    s_max - 2 - pos)``, hence ``pos + k_b`` stays within the worst-case
    block count and ``ensure_blocks_through`` can never fail.

    Output tokens are bit-identical to the non-speculative engines for any
    drafter and any sampling params (module docstring: the coupled-
    randomness rejection rule).  ``spec_mode``: "ngram" (prompt-lookup
    self-speculation) or "draft" (requires ``draft_cfg``/``draft_params``
    sharing the target vocab).
    """

    def __init__(self, cfg, params, *, spec_mode: str = "ngram",
                 spec_k: int = 4, draft_cfg=None, draft_params=None,
                 max_ngram: int = 3, **kw):
        super().__init__(cfg, params, **kw)
        if spec_k < 1:
            raise ValueError("spec_k must be >= 1 (use PagedServingEngine "
                             "for plain decode)")
        self.spec_k = spec_k
        self.spec_mode = spec_mode
        if spec_mode == "ngram":
            self.drafter = NgramDrafter(max_ngram=max_ngram)
        elif spec_mode == "draft":
            if draft_cfg is None or draft_params is None:
                raise ValueError("spec_mode='draft' needs draft_cfg and "
                                 "draft_params")
            self.drafter = DraftModelDrafter(
                cfg, draft_cfg, draft_params,
                batch_slots=self.batch_slots, s_max=self.s_max,
                spec_k=spec_k)
        else:
            raise ValueError(f"unknown spec_mode {spec_mode!r} "
                             "(expected 'ngram' or 'draft')")
        self.reset_spec_stats()

    # -- stats --------------------------------------------------------------
    def reset_spec_stats(self):
        """Zero the speculation counters only (cache state untouched)."""
        self.verify_forwards = 0   # verify forwards run (not prefills)
        self.row_verifies = 0      # (row, forward) pairs verified
        self.spec_tokens = 0       # tokens emitted by the decode phase
        self.drafted = 0           # draft tokens fed to verification
        self.accepted = 0          # draft tokens accepted
        self.rolled_back_blocks = 0

    def reset_stats(self):
        """Zero block AND speculation counters (bench warmup)."""
        super().reset_stats()
        self.reset_spec_stats()

    def stats(self) -> Dict[str, float]:
        """Paged-engine stats plus accept_rate (drafts accepted/proposed)
        and tokens_per_forward (emitted per row-verify; 1.0 = no win,
        spec_k + 1 = ceiling)."""
        s = super().stats()
        s.update(
            verify_forwards=self.verify_forwards,
            accept_rate=self.accepted / max(self.drafted, 1),
            # per-ROW decode forwards saved: 1.0 means no speculation win,
            # k+1 is the ceiling (all drafts + bonus accepted every step)
            tokens_per_forward=self.spec_tokens /
            max(self.row_verifies, 1),
            rolled_back_blocks=self.rolled_back_blocks,
        )
        return s

    # -- decode phase -------------------------------------------------------
    def _start_decode_slot(self, slot: int, req: Request, tok: int):
        super()._start_decode_slot(slot, req, tok)
        self.drafter.prefill(slot, req.prompt, tok)

    def _resume_decode_slot(self, slot: int, seq):
        """A preempted row may resume in a DIFFERENT slot: re-prefill the
        drafter there (the draft-model drafter's catch-up loop then replays
        the committed generated tokens before its next proposal, so
        proposals — and therefore accepts — pick up where they left off)."""
        super()._resume_decode_slot(slot, seq)
        self.drafter.prefill(slot, seq.request.prompt, seq.tokens[0])

    def _spec_budget(self, slot: int) -> int:
        """Draft tokens row `slot` may verify this step without writing
        past its reservation or past s_max - 2 (the last legal write)."""
        seq = self.scheduler.slots[slot]
        remaining = seq.request.max_new_tokens - len(seq.tokens)
        return max(0, min(self.spec_k, remaining - 1,
                          self.s_max - 2 - seq.pos))

    def _decode_phase(self, live: List[int]):
        jnp, np = self._jnp, self._np
        from repro.serving.sampler import GREEDY_EPS
        sched = self.scheduler

        budgets, contexts = {}, {}
        for slot in live:
            seq = sched.slots[slot]
            budgets[slot] = self._spec_budget(slot)
            contexts[slot] = seq.request.prompt + seq.tokens
        drafts = self.drafter.propose(live, contexts, budgets)

        k1 = self.spec_k + 1
        toks = np.zeros((self.batch_slots, k1), np.int32)
        klen = np.ones((self.batch_slots,), np.int32)
        for slot in live:
            if sched.slots[slot] is None:
                continue            # preempted as an earlier row's victim
            d = list(drafts.get(slot, []))[:budgets[slot]]
            toks[slot, 0] = self._tokens[slot]
            toks[slot, 1:1 + len(d)] = d
            klen[slot] = 1 + len(d)
            # preemption-aware (serving/memory.py): the slot may itself be
            # swapped out under pool pressure, dropping it from this round
            # — its drafts are simply discarded (a verify never ran, so
            # there is nothing to roll back)
            self._ensure_through(slot, int(self._pos[slot]) + len(d))
        live = [s for s in live if sched.slots[s] is not None]
        if not live:
            return []
        for slot in live:
            self._fill_bt_row(slot)

        w = self._bt_width(live)
        base = (self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(self._pos), jnp.asarray(self._active),
                jnp.asarray(klen), jnp.asarray(self._bt[:, :w]))
        if all(self._temp[s] <= GREEDY_EPS for s in live):
            self.caches, tgt = self._verify_greedy(*base)
        else:
            self.caches, tgt = self._verify(
                *base, jnp.asarray(self._temp), jnp.asarray(self._top_k),
                jnp.asarray(self._top_p), jnp.asarray(self._seeds))
        tgt = np.asarray(tgt)
        self.verify_forwards += 1

        events = []
        for slot in live:
            seq = sched.slots[slot]
            rid = seq.request.rid
            n_draft = int(klen[slot]) - 1
            self.drafted += n_draft
            self.row_verifies += 1
            retired = False
            last = None
            for i in range(int(klen[slot])):
                t = int(tgt[slot, i])
                events.append((rid, t))
                self.spec_tokens += 1
                matched = i < n_draft and t == int(toks[slot, i + 1])
                if matched:
                    self.accepted += 1
                if sched.observe(slot, t):
                    retired = True
                    break
                last = t
                if not matched:
                    break           # draft mismatch (or bonus token): stop
            if retired:
                self._active[slot] = False
            else:
                self._tokens[slot] = last
                self._pos[slot] = seq.pos
                self.rolled_back_blocks += sched.rollback_blocks(slot)
        return events
