"""Gradient compression for bandwidth-starved links (cross-pod DCN).

int8 quantization with per-block scales and error feedback: the residual
between the true gradient and its quantized image is carried to the next
step, so compression error accumulates boundedly instead of biasing the
trajectory (Seide et al. / EF-SGD family).

Intended placement: the POD axis.  Intra-pod (ICI) gradients stay full
precision; only the 4x-slower inter-pod reduction is compressed — pmean over
'pod' becomes quantize -> psum(int32 accumulate would overflow; we psum the
dequantized bf16 image, halving bytes vs fp32) -> dequantize + feedback.

``compressed_pmean`` is a drop-in for jax.lax.pmean over the pod axis.

The int8 quantize/dequantize primitives themselves now live in
``repro.quant`` (shared with the paged KV pool's int8 storage mode —
DESIGN.md §KV memory tiers); they are re-exported here so existing
imports and the EF-SGD call sites are untouched.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.quant import (BLOCK, dequantize_int8,  # noqa: F401  (re-export)
                         quantize_int8)


def compressed_pmean(grads, axis: str, error: Any = None):
    """EF-int8 pmean over `axis`.  Returns (grads_mean, new_error).

    error: pytree like grads carrying the feedback residual (or None).
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        img = dequantize_int8(q, scale, g.shape)
        new_e = target - img
        # the int8 image is what travels; psum of the dequantized image is
        # bit-equivalent to dequantize(psum(int32)) up to fp32 rounding and
        # keeps the collective in one fused op
        red = jax.lax.pmean(img.astype(jnp.bfloat16), axis)
        return red.astype(g.dtype), new_e

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(tree, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(tree, [o[1] for o in outs]))


def topk_sparsify(g: jnp.ndarray, k_frac: float = 0.01):
    """Top-k magnitude sparsification (values + indices), EF-compatible."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    picked = flat[idx]
    return picked, idx, flat.shape[0]


def topk_densify(vals, idx, n, shape):
    """Inverse of topk_sparsify: scatter (vals, idx) back into a dense
    zero-filled array of `shape` (n = flattened element count)."""
    flat = jnp.zeros((n,), jnp.float32).at[idx].set(vals)
    return flat.reshape(shape)
