"""Version shims for jax APIs that moved between releases.

The codebase targets the modern spellings (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``); on older
runtimes (0.4.x) these fall back to the experimental/legacy equivalents.
Everything mesh- or shard_map-shaped must go through this module so the
whole repo degrades together.
"""

from __future__ import annotations

import contextlib

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager activating `mesh` for jit'ed sharded computations."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # legacy global-mesh path: Mesh is itself a context manager
    return mesh if hasattr(mesh, "__enter__") else contextlib.nullcontext()


def shard_map(fn, mesh, in_specs, out_specs):
    """shard_map without value-and-replication checking (our step functions
    return TP-partial values on purpose)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
