"""Axis-aware collective wrappers.

All model code is written against an :class:`AxisEnv` instead of hard-coded
axis names.  When an axis is ``None`` (running outside ``shard_map``, e.g. in
single-device tests) every collective degrades to the identity, so the same
model function runs unchanged on one device and on a 512-chip mesh.

This module is also where the paper's mechanism lives operationally:
:meth:`AxisEnv.reduce_block_output` is the AllReduce that the Ladder
topology de-couples from the critical path, and :meth:`AxisEnv.psum_model`
is its one documented dispatch point.  ``mode="sync"`` leaves overlap to
XLA's latency-hiding scheduler (async ``all-reduce-start``/``done`` pairs —
the JAX analogue of the paper's ``AsyncAllReduce`` handle); ``overlap`` and
``compressed`` switch to the explicit chunked ring collectives in
:mod:`repro.parallel.overlap` (DESIGN.md §Communication overlap).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.overlap import (  # noqa: F401  (re-exported seam types)
    COMM_MODES,
    SYNC,
    CommConfig,
    PendingResidual,
    compressed_ring_all_reduce,
    local_block_images,
    ring_all_reduce,
    ring_block_images,
)


def _axis_size(name) -> int:
    """Static mesh-axis size.  Version shim: ``jax.lax.axis_size`` is
    recent; on older jax a psum of a python scalar constant-folds to the
    axis size."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


@dataclass(frozen=True)
class AxisEnv:
    """Names of the live mesh axes inside the current shard_map (or None)."""

    model: Optional[str] = None   # tensor-parallel axis
    data: Optional[str] = None    # data-parallel axis
    pod: Optional[str] = None     # pod axis (extra DP or pipeline stages)
    sp: bool = False              # Megatron-style sequence parallelism on/off
    comm: CommConfig = field(default_factory=CommConfig)  # AllReduce mode

    @property
    def tp(self) -> int:
        """Tensor-parallel degree (1 outside a model-axis shard_map)."""
        return _axis_size(self.model) if self.model else 1

    @property
    def dp(self) -> int:
        """Data-parallel degree of the `data` axis alone (see dp_total)."""
        return _axis_size(self.data) if self.data else 1

    def model_axis_index(self):
        """This shard's index on the model axis (0 when unsharded)."""
        return jax.lax.axis_index(self.model) if self.model else 0

    def data_axis_index(self):
        """This shard's index on the data axis (0 when unsharded)."""
        return jax.lax.axis_index(self.data) if self.data else 0

    # ---- collectives over the tensor-parallel axis ------------------------
    def psum_model(self, x):
        """AllReduce over TP shards — THE comm seam, and its one dispatch
        point (satellite fix for the old silent per-call-site branching).

        Modes (``self.comm.mode``):

        ``sync``
            one ``jax.lax.psum``; overlap is left to XLA's scheduler.
        ``overlap``
            chunked ppermute/DMA ring (:func:`repro.parallel.overlap.
            ring_all_reduce`) — bit-equal to sync at tp=2, within rounding
            above; chunk ``i``'s hops pipeline under chunk ``i+1``.
        ``compressed``
            int8-on-wire ring — ~2x fewer wire bytes, bounded error
            (callers opt in; NOT bit-identical to sync).

        Unsharded (``self.model`` falsy) is the *documented* degenerate
        path: every mode is the identity, because the single-shard sum is
        the shard value itself.  An invalid mode raises here — even
        unsharded — rather than falling through to sync; ``CommConfig``
        validates at construction, and this guards hand-rolled configs
        (tests poke one in with ``object.__setattr__``).
        """
        mode = self.comm.mode
        if mode not in COMM_MODES:
            raise ValueError(
                f"invalid comm mode {mode!r}; expected one of {COMM_MODES}"
            )
        if not self.model:
            return x
        if mode == "overlap":
            return ring_all_reduce(x, self.model, chunks=self.comm.chunks)
        if mode == "compressed":
            return compressed_ring_all_reduce(
                x, self.model, chunks=self.comm.chunks
            )
        return jax.lax.psum(x, self.model)

    def ring_block_output_images(self, x) -> PendingResidual:
        """Deferred block-output AllReduce (``comm.fuse_norm``): the int8
        ring delivers the source-ordered per-shard image stack
        (:class:`~repro.parallel.overlap.PendingResidual`) and the
        dequant-sum is left to the consumer — the ladder topology's next
        sub-block, whose RMSNorm fuses it (kernels/rmsnorm.rmsnorm_dequant).

        Only the LADDER wiring calls this (core/residual.py): a deferred
        pending IS what a ladder carry holds, whereas the standard topology
        consumes the reduction immediately.  Unsharded is NOT the identity:
        the shard's own partial still quantizes into a one-source stack, so
        TP=1 exercises the same deferred-dequant numerics as the ring."""
        if not self.model:
            return local_block_images(x)
        return ring_block_images(x, self.model, chunks=self.comm.chunks)

    def pmax_model(self, x):
        """Differentiation-safe max over the model axis (pmax lacks a JVP
        rule; all_gather has one and the gradient of max-of-gather is what we
        want for stop-gradient uses anyway)."""
        if not self.model:
            return x
        return jnp.max(jax.lax.all_gather(x, self.model), axis=0)

    def all_gather_model(self, x, axis: int = 0, tiled: bool = True):
        """Concatenate TP shards along `axis` (tiled: no new leading dim)."""
        if not self.model:
            return x
        return jax.lax.all_gather(x, self.model, axis=axis, tiled=tiled)

    def reduce_scatter_model(self, x, axis: int = 0):
        """Sum over TP shards, each keeping its `axis` slice (SP exit)."""
        if not self.model:
            return x
        return jax.lax.psum_scatter(x, self.model, scatter_dimension=axis,
                                    tiled=True)

    # ---- collectives over the data axes ----------------------------------
    def _dp_axes(self):
        # pod-major ordering matches mesh axis order (pod, data, model)
        axes = tuple(a for a in (self.pod, self.data) if a)
        return axes

    @property
    def dp_total(self) -> int:
        """Joint data-parallel degree over the (pod, data) axes."""
        n = 1
        for a in self._dp_axes():
            n *= _axis_size(a)
        return n

    def dp_shard_index(self):
        """Linear index over the joint (pod, data) grid."""
        idx = 0
        for a in self._dp_axes():
            idx = idx * _axis_size(a) + jax.lax.axis_index(a)
        return idx

    def all_gather_dp(self, x, axis: int = 0, tiled: bool = False):
        """Gather over the joint (pod, data) grid (flash-decode combine)."""
        axes = self._dp_axes()
        return jax.lax.all_gather(x, axes, axis=axis, tiled=tiled) \
            if axes else x

    def psum_dp(self, x):
        """Sum over the joint (pod, data) grid."""
        axes = self._dp_axes()
        return jax.lax.psum(x, axes) if axes else x

    def pmean_grads(self, tree):
        """Mean every gradient leaf over the DP grid (the train-step
        gradient sync; see compression.compressed_pmean for the EF-int8
        variant)."""
        axes = self._dp_axes()
        if not axes:
            return tree
        return jax.tree.map(lambda g: jax.lax.pmean(g, axes), tree)

    def psum_data(self, x):
        """Alias of psum_dp (metric reductions read better with it)."""
        axes = self._dp_axes()
        return jax.lax.psum(x, axes) if axes else x

    def pmean_data(self, x):
        """Mean over the joint (pod, data) grid (loss/metric averaging)."""
        axes = self._dp_axes()
        return jax.lax.pmean(x, axes) if axes else x

    def all_gather_data(self, x, axis: int = 0, tiled: bool = True):
        """Gather over the `data` axis only (not pod)."""
        if not self.data:
            return x
        return jax.lax.all_gather(x, self.data, axis=axis, tiled=tiled)

    # ---- sequence parallelism ---------------------------------------------
    # With SP on, the residual stream lives seq-sharded across the model axis.
    # Blocks all-gather the sequence at entry and reduce-scatter at exit;
    # the reduce-scatter plays the AllReduce's role in the Ladder schedule.
    def sp_gather(self, x, seq_axis: int = 1):
        """SP block entry: all-gather the seq-sharded residual stream
        ((B, S/tp, D) -> (B, S, D)); identity with SP off."""
        if self.sp and self.model:
            return jax.lax.all_gather(x, self.model, axis=seq_axis, tiled=True)
        return x

    def sp_reduce(self, x, seq_axis: int = 1):
        """SP block exit reduction (alias of :meth:`reduce_block_output`,
        kept for callers that read better with the SP name)."""
        return self.reduce_block_output(x, seq_axis=seq_axis)

    def reduce_block_output(self, x, seq_axis: int = 1):
        """Sub-block exit reduction — the single call site for
        core/residual.py (no per-site ``env.sp`` branching).

        SP on: reduce-scatter back to (B, S/tp, D); stays synchronous by
        design — the scattered slice is this shard's own residual segment
        and is consumed immediately, so there is nothing to overlap.
        SP off: :meth:`psum_model`, i.e. the sync/overlap/compressed
        dispatch."""
        if self.sp and self.model:
            return jax.lax.psum_scatter(x, self.model,
                                        scatter_dimension=seq_axis, tiled=True)
        return self.psum_model(x)


# A null environment for single-device execution / oracles.
NULL_ENV = AxisEnv()
