"""Sharded step builders: explicit shard_map tensor parallelism.

Everything runs manually partitioned over the full mesh: the model axis
carries Megatron-style TP (with the residual topology owning the psums —
the paper's mechanism), the data (+pod) axes carry DP.  Collective placement
is therefore deterministic and countable, which the roofline analysis relies
on.

Subtleties handled here:
* TP-aware gradient global-norm: sharded leaves need a psum over the model
  axis; replicated leaves must not be double counted.
* Replicated-parameter gradients (norms, routers) are identical across model
  shards under STANDARD topology but diverge under DESYNC (per-shard
  activations differ) and under sequence parallelism — those modes pmean
  them over the model axis (the Megatron SP rule).
* KV-head replicas (tp > n_kv_heads) get gradient-averaged so replicas stay
  bit-identical (sharding.kv_replica_grad_sync).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ModelConfig, ParallelConfig, ResidualMode,
                                TrainConfig)
from repro.models import transformer as tfm
from repro.models.layers import sharded_cross_entropy
from repro.parallel import sharding
from repro.parallel.collectives import AxisEnv, CommConfig
from repro.training import optimizer as opt


def make_axis_env(pcfg: ParallelConfig,
                  comm: Optional[CommConfig] = None) -> AxisEnv:
    """AxisEnv naming only the mesh axes `pcfg` actually uses (absent
    axes stay None so collectives degrade to identity).  `comm` selects
    the block-output AllReduce implementation (default: sync psum)."""
    return AxisEnv(
        model="model" if pcfg.tp > 1 else None,
        data="data" if pcfg.dp > 1 else None,
        pod="pod" if (pcfg.pods > 1 or pcfg.pp > 1) else None,
        sp=pcfg.use_sp,
        comm=comm if comm is not None else CommConfig())


def _dp_axes_present(pcfg: ParallelConfig):
    axes = []
    if pcfg.pods > 1:
        axes.append("pod")
    if pcfg.dp > 1:
        axes.append("data")
    return tuple(axes)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            env: AxisEnv, tcfg: Optional[TrainConfig] = None,
            train: bool = True, section_gathers=None):
    """Causal LM loss with vocab-sharded logits (never materialises the full
    logits tensor).  Returns (loss, metrics)."""
    kw = {}
    if cfg.family == "vlm":
        kw["frontend_embeds"] = batch["patches"]
    if cfg.encoder_layers:
        kw["frontend_embeds"] = batch["frames"]
    hidden, _, aux = tfm.forward(cfg, params, batch["tokens"], env,
                                 train=train, section_gathers=section_gathers,
                                 **kw)
    if cfg.family == "vlm":
        hidden = hidden[:, -batch["tokens"].shape[1]:]
    logits = tfm.logits_shard(cfg, params, hidden)
    z_loss = tcfg.z_loss if tcfg else 0.0
    nll = sharded_cross_entropy(logits, batch["targets"], env, z_loss=z_loss,
                                true_vocab=cfg.vocab_size)
    mask = batch.get("loss_mask")
    if mask is not None:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    else:
        loss = jnp.mean(nll)
    loss = loss + aux
    return loss, dict(nll=jnp.mean(nll), aux=aux)


def _grad_square_sum(grads, specs, env: AxisEnv):
    """Sharding-correct sum of squared gradients.

    Each leaf's squares are summed over exactly the mesh axes its spec
    shards it on (model, data, or both for FSDP flat leaves); replicated
    leaves are counted once."""
    buckets = {}
    for g, s in zip(jax.tree.leaves(grads), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        axes = []
        if sharding.spec_has(s, "model") and env.model:
            axes.append(env.model)
        if sharding.spec_has(s, "data") and env.data:
            axes.append(env.data)
        key = tuple(axes)
        buckets[key] = buckets.get(key, 0.0) + jnp.sum(
            jnp.square(g.astype(jnp.float32)))
    tot = jnp.zeros((), jnp.float32)
    for axes, sq in buckets.items():
        tot = tot + (jax.lax.psum(sq, axes) if axes else sq)
    return tot


def _sync_replicated_grads(grads, specs, env: AxisEnv):
    def fix(g, s):
        if sharding.spec_has(s, "model"):
            return g
        return jax.lax.pmean(g, env.model) if env.model else g
    return jax.tree.map(fix, grads, specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh, pcfg: ParallelConfig,
                     tcfg: TrainConfig, *, zero1: bool = False,
                     fsdp: bool = False):
    """Returns (step_fn, in_specs, out_specs).

    step_fn(params, opt_state, batch, step) -> (params, opt_state, metrics)
    and is already shard_map'ped (call under ``jax.jit`` with the mesh set).

    fsdp: store section params flat-sharded over data (ZeRO-3); gradients
    for them arrive DP-reduced via the all_gather transpose and the AdamW
    states are implicitly ZeRO-sharded.
    """
    env = make_axis_env(pcfg)
    specs_tree = tfm.param_specs(cfg)
    pspecs = sharding.param_pspecs(specs_tree)
    lr_fn = opt.lr_schedule(tcfg)
    dp_axes = _dp_axes_present(pcfg)
    needs_repl_sync = env.sp or cfg.residual_mode in (
        ResidualMode.DESYNC2, ResidualMode.DESYNC4)

    gathers = None
    if fsdp:
        from repro.parallel import fsdp as fsdp_mod
        # prepared (padded) section specs + their flat sharded layout
        prep_specs = jax.eval_shape(
            lambda: sharding.prepare_params_for_tp(
                tfm.init_params(cfg, jax.random.key(0)), cfg, pcfg.tp)[0])
        sec_pspecs = sharding.param_pspecs(prep_specs)["sections"]
        meta = fsdp_mod.sections_meta(prep_specs["sections"], sec_pspecs,
                                      pcfg.tp, pcfg.dp)
        pspecs = dict(sharding.param_pspecs(prep_specs))
        pspecs["sections"] = fsdp_mod.flat_pspecs(sec_pspecs)
        gathers = fsdp_mod.make_section_gathers(list(meta), env)

    def loss_fn(params, batch):
        return lm_loss(cfg, params, batch, env, tcfg, train=True,
                       section_gathers=gathers)

    def step(params, opt_state, batch, step_idx):
        if tcfg.grad_accum > 1:
            def micro(accum, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return jax.tree.map(jnp.add, accum,
                                    (g, l, m["nll"])), None
            zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params), jnp.zeros(()), jnp.zeros(()))
            mbs = jax.tree.map(
                lambda t: t.reshape(tcfg.grad_accum,
                                    t.shape[0] // tcfg.grad_accum,
                                    *t.shape[1:]), batch)
            (grads, loss, nll), _ = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            loss, nll = loss / tcfg.grad_accum, nll / tcfg.grad_accum
            metrics = dict(nll=nll)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if needs_repl_sync:
            grads = _sync_replicated_grads(grads, pspecs, env)
        if pcfg.tp > 1 and not fsdp:
            grads = sharding.kv_replica_grad_sync(grads, cfg, pcfg.tp)

        lr = lr_fn(step_idx)
        if fsdp:
            # Section grads arrived DP-SUMMED via the all_gather transpose
            # (reduce-scatter); scale them to the DP mean.  Everything else
            # still needs the explicit DP mean.
            def fix(path, g):
                keys = [str(getattr(p, "key", "")) for p in path]
                if keys and keys[0] == "sections":
                    g = g / max(pcfg.dp, 1)
                    if pcfg.pods > 1:
                        g = jax.lax.pmean(g, "pod")
                    return g
                return jax.lax.pmean(g, dp_axes) if dp_axes else g
            grads = jax.tree_util.tree_map_with_path(fix, grads)
            gn = jnp.sqrt(_grad_square_sum(grads, pspecs, env))
            scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
            params2, opt_state2 = opt.adamw_update(
                grads, opt_state, params, lr=lr, cfg=tcfg)
        elif zero1:
            # DP mean happens inside the reduce-scatter
            gsq = _grad_square_sum(grads, pspecs, env)
            gsq = env.psum_data(gsq) / max(
                pcfg.dp * pcfg.pods, 1)  # approx pre-reduction norm
            gn = jnp.sqrt(gsq)
            scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
            params2, opt_state2 = opt.zero1_update(
                grads, opt_state, params, lr=lr, cfg=tcfg, env=env)
        else:
            if dp_axes:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axes),
                                     grads)
            gn = jnp.sqrt(_grad_square_sum(grads, pspecs, env))
            scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
            params2, opt_state2 = opt.adamw_update(
                grads, opt_state, params, lr=lr, cfg=tcfg)

        loss = env.pmean_data(loss)
        metrics = dict(loss=loss, grad_norm=gn, lr=lr,
                       nll=env.pmean_data(metrics["nll"]))
        return params2, opt_state2, metrics

    batch_spec = _batch_specs(cfg, pcfg)
    opt_specs = opt_state_pspecs(cfg, pspecs, zero1=zero1 and not fsdp,
                                 pcfg=pcfg)
    in_specs = (pspecs, opt_specs, batch_spec, P())
    out_specs = (pspecs, opt_specs,
                 dict(loss=P(), grad_norm=P(), lr=P(), nll=P()))
    from repro.parallel import compat
    mapped = compat.shard_map(step, mesh, in_specs, out_specs)
    return mapped, in_specs, out_specs


def _batch_specs(cfg: ModelConfig, pcfg: ParallelConfig):
    b = P(("pod", "data")) if (pcfg.pods > 1 or pcfg.pp > 1) else \
        (P("data") if pcfg.dp > 1 else P())
    spec = dict(tokens=b, targets=b)
    if cfg.family == "vlm":
        spec["patches"] = b
    if cfg.encoder_layers:
        spec["frames"] = b
    return spec


def opt_state_pspecs(cfg: ModelConfig, pspecs, *, zero1: bool,
                     pcfg: ParallelConfig):
    """PartitionSpecs for the AdamW state: moments/master follow the
    parameter specs; with ZeRO-1 they are flat-sharded over the joint
    ('model', 'data') grid instead (each shard owns a 1/world slice)."""
    if not zero1:
        return opt.AdamWState(
            step=P(), mu=jax.tree.map(lambda s: s, pspecs),
            nu=jax.tree.map(lambda s: s, pspecs),
            master=jax.tree.map(lambda s: s, pspecs))

    def flat_spec(s):
        return P(("model", "data")) if sharding.spec_has(s, "model") \
            else P("data")

    fs = jax.tree.map(flat_spec, pspecs,
                      is_leaf=lambda x: isinstance(x, P))
    return opt.AdamWState(step=P(), mu=fs, nu=jax.tree.map(lambda s: s, fs),
                          master=jax.tree.map(lambda s: s, fs))


def init_train_state(cfg: ModelConfig, pcfg: ParallelConfig, key,
                     zero1: bool = False, fsdp: bool = False):
    """Host-side init of (params, opt_state) in the prepared TP layout."""
    params = tfm.init_params(cfg, key)
    params, masks = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
    if fsdp:
        from repro.parallel import fsdp as fsdp_mod
        sec_pspecs = sharding.param_pspecs(params)["sections"]
        flat, _ = fsdp_mod.flatten_sections_host(
            params["sections"], sec_pspecs, pcfg.tp, pcfg.dp)
        params = dict(params)
        params["sections"] = flat
        state = opt.adamw_init(params)
    elif zero1:
        pspecs = sharding.param_pspecs(params)
        state = opt.zero1_init(params, pspecs, pcfg.tp, pcfg.dp)
    else:
        state = opt.adamw_init(params)
    return params, state, masks
