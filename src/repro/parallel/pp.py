"""Pipeline parallelism over the pod axis (GPipe-style, shard_map manual).

For multi-pod runs the 'pod' axis can carry pipeline stages instead of extra
DP: each pod holds a contiguous slice of layer groups (params arrive sliced
via P("pod", ...) on the stack dim) and microbatches flow stage-to-stage via
collective_permute over the inter-pod links.

Schedule: GPipe with M microbatches over P stages: T = M + P - 1 ticks.
At tick t, stage s computes microbatch (t - s) when 0 <= t - s < M.
Bubble fraction = (P-1)/(M+P-1); the driver exposes M so callers trade
bubble for activation memory.  The whole schedule is differentiable
(collective_permute transposes to the reverse permute), so the same driver
serves training.

The residual-topology CARRY (including the Ladder pending pair) travels
through the inter-stage permute, so pipelined Ladder is mathematically
identical to the single-stage model — the in-flight psum of the stage's
last sub-block overlaps with the ppermute hop, compounding the paper's
overlap across the slowest links.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ResidualMode
from repro.core import residual as topo
from repro.parallel.collectives import AxisEnv


def pipeline_stack(mode: ResidualMode, fns: Sequence, params_stage,
                   x_micro, env: AxisEnv, *, n_stages: int,
                   remat: str = "none"):
    """Run the layer stack split across pipeline stages.

    params_stage: this stage's stacked group params (G_local, ...) — the
      global stack sharded P("pod", ...) on dim 0.
    x_micro: (M, B_micro, S, D) microbatched embeddings (replicated across
      stages; only stage 0 consumes them).
    Returns ((M, B_micro, S, D) final hidden states [valid on all stages],
             aux loss).
    """
    m = x_micro.shape[0]
    stage = jax.lax.axis_index(env.pod)
    ticks = m + n_stages - 1
    perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def run_groups(carry_tuple, base_idx):
        c = topo._carry_from_tuple(mode, carry_tuple)
        c, _ = topo.run_section(mode, fns, params_stage, c, env,
                                sub_idx0=base_idx, remat=remat,
                                use_scan=True)
        return c.tree()

    proto = topo.init_carry(mode, x_micro[0]).tree()

    def tick(state, t):
        buf, outs, aux_acc = state          # buf: carry tuple in flight
        mb_idx = t - stage
        active = (mb_idx >= 0) & (mb_idx < m)
        fresh = topo.init_carry(mode, x_micro[jnp.clip(mb_idx, 0, m - 1)])
        carry_in = jax.tree.map(
            lambda f, b: jnp.where(stage == 0, f, b), fresh.tree(), buf)
        # NOTE: desync phases need subs_per_stage % desync_n == 0; the
        # launcher asserts this when selecting PP for desync configs.
        carry_out = run_groups(carry_in, 0)
        carry_out = jax.tree.map(
            lambda y, b: jnp.where(active, y, b), carry_out, buf)
        # last stage flushes pendings and records the finished microbatch
        c_fin = topo._carry_from_tuple(mode, carry_out)
        r, aux = topo.finalize_carry(mode, c_fin, env)
        out_idx = t - (n_stages - 1)
        record = (stage == n_stages - 1) & (out_idx >= 0) & (out_idx < m)
        outs = outs.at[jnp.clip(out_idx, 0, m - 1)].set(
            jnp.where(record, r, outs[jnp.clip(out_idx, 0, m - 1)]))
        aux_acc = aux_acc + jnp.where(record, aux, 0.0)
        # hand the carry to the next stage
        buf = jax.tree.map(
            lambda y: jax.lax.ppermute(y, env.pod, perm=perm_fwd), carry_out)
        return (buf, outs, aux_acc), None

    outs0 = jnp.zeros_like(x_micro)
    (_, outs, aux), _ = jax.lax.scan(
        tick, (proto, outs0, jnp.zeros((), jnp.float32)), jnp.arange(ticks))
    # broadcast the last stage's results to every stage (SPMD uniformity):
    # masked psum — every other stage contributes zeros
    last = stage == n_stages - 1
    outs = jax.lax.psum(jnp.where(last, outs, jnp.zeros_like(outs)), env.pod)
    aux = jax.lax.psum(jnp.where(last, aux, 0.0), env.pod)
    return outs, aux


def stage_param_spec(spec):
    """Turn a stacked-section PartitionSpec into its pipeline version:
    the group-stack dim (dim 0) is sharded over 'pod'."""
    from jax.sharding import PartitionSpec as P
    rest = tuple(spec)[1:]
    return P("pod", *rest)
