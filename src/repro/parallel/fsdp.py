"""ZeRO-3 / FSDP for the section (layer-stack) parameters.

Section parameters — the overwhelming bulk of model weight — are stored
flat-sharded over the data axis: each leaf (G, *rest) becomes
(G, tp*dp*chunk) (TP-sharded leaves, spec P(None, ("model","data"))) or
(G, dp*chunk) (TP-replicated leaves, spec P(None, "data")), so a device
holds (G, chunk).  Inside the scan body over layer groups the group's flat
shard is all-gathered, sliced and reshaped back to the TP-local parameter —
a transient of ONE group's size.  This is what makes dbrx-132B training fit
a 16 GB v5e chip.

The payoff of expressing this with a differentiable all_gather: its autodiff
transpose is a reduce-scatter, so the backward pass produces DP-reduced
gradient *shards* directly — FSDP gradient sync for free, with RS+AG bytes
replacing the DP all-reduce, and the XLA latency-hiding scheduler overlaps
each group's gather with the previous group's compute (weight prefetch).

Under remat the gathers are recomputed in the backward pass instead of
keeping gathered weights alive — the standard FSDP memory/time trade.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.collectives import AxisEnv
from repro.parallel.sharding import spec_has


def _flat_size(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


class LeafMeta:
    """Opaque (non-pytree) record describing one section leaf."""

    __slots__ = ("shape", "size", "chunk", "model_dim")

    def __init__(self, shape, size, chunk, model_dim):
        self.shape = shape          # TP-local per-group shape (no G dim)
        self.size = size            # flat size of `shape`
        self.chunk = chunk          # per-device flat chunk (ceil(size/dp))
        self.model_dim = model_dim  # model-sharded dim in the FULL leaf, or -1

    def __repr__(self):
        return f"LeafMeta({self.shape}, chunk={self.chunk}, md={self.model_dim})"


def _model_dim(spec: P) -> int:
    for i, ax in enumerate(tuple(spec)):
        names = ax if isinstance(ax, (tuple, list)) else (ax,)
        if "model" in names:
            return i
    return -1


def local_shape(full_shape, spec: P, tp: int) -> Tuple[int, ...]:
    """Per-TP-shard shape of a global array: the dim carrying 'model' in
    `spec` divides by tp, everything else is unchanged."""
    dims = list(full_shape)
    md = _model_dim(spec)
    if md >= 0:
        dims[md] //= tp
    return tuple(dims)


def sections_meta(sections_specs, sections_pspecs, tp: int, dp: int):
    """Pytree (matching section params) of LeafMeta."""
    def meta(leaf, spec):
        lshape = local_shape(leaf.shape, spec, tp)[1:]   # drop G dim
        size = _flat_size(lshape)
        return LeafMeta(lshape, size, -(-size // dp), _model_dim(spec))
    return jax.tree.map(meta, sections_specs, sections_pspecs)


def flatten_sections_host(sections, pspecs_sections, tp: int, dp: int):
    """Host-side: rewrite TP-PREPARED GLOBAL section params into the
    flat-sharded layout.  Returns (flat_sections, flat_pspecs)."""

    def flat(leaf, spec):
        g = leaf.shape[0]
        md = _model_dim(spec)
        if md >= 0:
            arr = jnp.moveaxis(leaf, md, 1)          # (G, model_full, ...)
            arr = arr.reshape(g, tp, -1)             # (G, tp, local_flat)
            size = arr.shape[-1]
            chunk = -(-size // dp)
            arr = jnp.pad(arr, ((0, 0), (0, 0), (0, chunk * dp - size)))
            return arr.reshape(g, tp * dp * chunk)
        size = _flat_size(leaf.shape[1:])
        chunk = -(-size // dp)
        return jnp.pad(leaf.reshape(g, size),
                       ((0, 0), (0, chunk * dp - size)))

    flat_params = jax.tree.map(flat, sections, pspecs_sections)
    return flat_params, flat_pspecs(pspecs_sections)


def flat_pspecs(pspecs_sections):
    """Specs of the flat-sharded layout (no array work — dry-run safe)."""
    def fspec(spec):
        return P(None, ("model", "data")) if spec_has(spec, "model") \
            else P(None, "data")
    return jax.tree.map(fspec, pspecs_sections,
                        is_leaf=lambda x: isinstance(x, P))


def make_section_gathers(meta_sections, env: AxisEnv):
    """Returns gathers[i]: fn(group_flat_params) -> TP-local group params."""

    def gather_leaf(flat, meta: LeafMeta):
        if env.data:
            full = jax.lax.all_gather(flat, env.data, axis=0, tiled=True)
        else:
            full = flat
        full = full[:meta.size]
        if meta.model_dim >= 0:
            d = meta.model_dim - 1                   # dim in per-group shape
            moved = (meta.shape[d],) + tuple(
                s for i, s in enumerate(meta.shape) if i != d)
            return jnp.moveaxis(full.reshape(moved), 0, d)
        return full.reshape(meta.shape)

    def make(sec_meta):
        def gather(group_params):
            return jax.tree.map(gather_leaf, group_params, sec_meta)
        return gather

    return [make(m) for m in meta_sections]


# ---------------------------------------------------------------------------
# int8-quantized weight gathers (serving fit/bandwidth: §Perf HC3)
# ---------------------------------------------------------------------------

Q8_BLOCK = 256


def _chunk_q8(size: int, dp: int) -> int:
    """Per-device flat chunk, rounded so quant blocks never straddle
    device boundaries."""
    chunk = -(-size // dp)
    return -(-chunk // Q8_BLOCK) * Q8_BLOCK


def flatten_sections_host_q8(sections, pspecs_sections, tp: int, dp: int):
    """Like flatten_sections_host, but stores int8 + per-256-block fp32
    scales: the per-step FSDP weight all-gather moves ~0.52x the bytes
    (1B payload + 4B/256 scales vs 2B bf16).  Serving-only (weights are
    quantized once at load)."""

    def flat_q8(leaf, spec):
        g = leaf.shape[0]
        md = _model_dim(spec)
        if md >= 0:
            arr = jnp.moveaxis(leaf, md, 1).reshape(g, tp, -1)
            size = arr.shape[-1]
            chunk = _chunk_q8(size, dp)
            arr = jnp.pad(arr, ((0, 0), (0, 0), (0, chunk * dp - size)))
            arr = arr.reshape(g, tp * dp * chunk)
        else:
            size = _flat_size(leaf.shape[1:])
            chunk = _chunk_q8(size, dp)
            arr = jnp.pad(leaf.reshape(g, size),
                          ((0, 0), (0, chunk * dp - size)))
        blocks = arr.astype(jnp.float32).reshape(g, -1, Q8_BLOCK)
        scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
        q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12))
        return dict(q=q.astype(jnp.int8).reshape(g, -1),
                    s=scale.astype(jnp.float32))

    return jax.tree.map(flat_q8, sections, pspecs_sections)


def flat_pspecs_q8(pspecs_sections):
    """PartitionSpecs for the int8-flat layout: each leaf becomes a
    {q, s} dict flat-sharded over ('model', 'data') (or 'data' when the
    original leaf was replicated across TP)."""
    def fspec(spec):
        ax = ("model", "data") if spec_has(spec, "model") else "data"
        return dict(q=P(None, ax), s=P(None, ax))
    return jax.tree.map(fspec, pspecs_sections,
                        is_leaf=lambda x: isinstance(x, P))


def make_section_gathers_q8(meta_sections, env: AxisEnv):
    """gathers[i](group_q8_params) -> dequantized TP-local group params.
    The all-gather moves int8 + scales; dequantisation happens post-gather
    on-device (VPU work, overlapped by the scheduler)."""

    def gather_leaf(q8, meta: LeafMeta):
        q, s = q8["q"], q8["s"]
        if env.data:
            q = jax.lax.all_gather(q, env.data, axis=0, tiled=True)
            s = jax.lax.all_gather(s, env.data, axis=0, tiled=True)
        x = (q.astype(jnp.float32).reshape(-1, Q8_BLOCK)
             * s[:, None]).reshape(-1)
        full = x[:meta.size].astype(jnp.bfloat16)
        if meta.model_dim >= 0:
            d = meta.model_dim - 1
            moved = (meta.shape[d],) + tuple(
                sh for i, sh in enumerate(meta.shape) if i != d)
            return jnp.moveaxis(full.reshape(moved), 0, d)
        return full.reshape(meta.shape)

    def make(sec_meta):
        def gather(group_params):
            return jax.tree.map(gather_leaf, group_params, sec_meta,
                                is_leaf=lambda x: isinstance(x, dict)
                                and "q" in x)
        return gather

    return [make(m) for m in meta_sections]


def sections_meta_q8(sections_specs, sections_pspecs, tp: int, dp: int):
    """Meta with chunks rounded to the q8 block so scales align."""
    def meta(leaf, spec):
        lshape = local_shape(leaf.shape, spec, tp)[1:]
        size = _flat_size(lshape)
        return LeafMeta(lshape, size, _chunk_q8(size, dp), _model_dim(spec))
    return jax.tree.map(meta, sections_specs, sections_pspecs)
