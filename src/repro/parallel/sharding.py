"""Parameter sharding rules and TP preparation.

Three jobs:

1. ``tp_head_plan`` — decide how attention heads map onto the model axis
   when head counts don't divide TP (phi4: 24H/kv8, gemma3: 8H/kv4,
   whisper: 12H MHA at TP=16).  KV heads are replicated (standard Megatron
   GQA serving practice) and query heads zero-padded; padded-head parameters
   are frozen via ``param_masks`` so training at TP stays mathematically
   identical to the unpadded model.  The padding overhead is visible in the
   roofline's MODEL_FLOPS/HLO_FLOPs ratio by construction (honest
   accounting).

2. ``prepare_params_for_tp`` — rewrite full parameters into the padded /
   replicated layout (on real systems this happens once at checkpoint load).

3. ``param_pspecs`` — name-based PartitionSpec rules for every leaf.  Leading
   stack dims (scan groups, experts handled explicitly) map to None.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# head planning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HeadPlan:
    """How query/KV heads map onto TP shards: effective (padded or
    replicated) head counts plus eff-slot -> original-head index maps
    (-1 marks a zero pad).  Produced by ``tp_head_plan``."""
    tp: int
    h_eff: int                 # padded query-head count (divisible by tp)
    kv_eff: int                # replicated/padded kv-head count
    q_map: Tuple[int, ...]     # eff q slot -> orig q head (-1 = zero pad)
    kv_map: Tuple[int, ...]    # eff kv slot -> orig kv head (-1 = zero pad)

    @property
    def padded(self) -> bool:
        return self.q_map != tuple(range(self.h_eff)) or \
            self.kv_map != tuple(range(self.kv_eff))


def tp_head_plan(n_heads: int, n_kv: int, tp: int) -> HeadPlan:
    """Head layout for `tp` shards: exact split when tp | n_kv, KV
    replication when n_kv < tp | n_kv * r, zero-padding of both maps
    otherwise — callers size caches with hp.kv_eff, not cfg.n_kv_heads."""
    g = n_heads // n_kv
    if n_kv % tp == 0:
        return HeadPlan(tp, n_heads, n_kv, tuple(range(n_heads)),
                        tuple(range(n_kv)))
    if n_kv < tp and tp % n_kv == 0:
        r = tp // n_kv                     # kv replication factor
        g_eff = -(-g // r)                 # q heads per kv replica
        q_map, kv_map = [], []
        for kv in range(n_kv):
            for rep in range(r):
                kv_map.append(kv)
                for t in range(g_eff):
                    q = kv * g + rep * g_eff + t
                    q_map.append(q if rep * g_eff + t < g else -1)
        return HeadPlan(tp, len(q_map), len(kv_map), tuple(q_map),
                        tuple(kv_map))
    if n_heads == n_kv:                    # MHA with awkward head count
        h_eff = -(-n_heads // tp) * tp
        m = tuple(i if i < n_heads else -1 for i in range(h_eff))
        return HeadPlan(tp, h_eff, h_eff, m, m)
    raise ValueError(f"unsupported head layout: H={n_heads} KV={n_kv} tp={tp}")


def _remap_cols(w, head_map, hd, orig_heads):
    """w: (..., in, orig_heads*hd) -> (..., in, len(head_map)*hd)."""
    ws = w.reshape(*w.shape[:-1], orig_heads, hd)
    idx = np.asarray([h if h >= 0 else 0 for h in head_map])
    out = jnp.take(ws, idx, axis=-2)
    mask = np.asarray([h >= 0 for h in head_map])
    out = out * jnp.asarray(mask, out.dtype)[..., None]
    return out.reshape(*w.shape[:-1], len(head_map) * hd)


def _remap_rows(w, head_map, hd, orig_heads):
    """w: (..., orig_heads*hd, out) -> padded rows.  Padded slots are zero;
    replicated slots would double-count, but q heads are never replicated."""
    ws = w.reshape(*w.shape[:-2], orig_heads, hd, w.shape[-1])
    idx = np.asarray([h if h >= 0 else 0 for h in head_map])
    out = jnp.take(ws, idx, axis=-3)
    mask = np.asarray([h >= 0 for h in head_map])
    out = out * jnp.asarray(mask, out.dtype)[..., None, None]
    return out.reshape(*w.shape[:-2], len(head_map) * hd, w.shape[-1])


# ---------------------------------------------------------------------------
# leaf rules
# ---------------------------------------------------------------------------

# name -> (shard dim counted from the end, kind)
_COL = {"wq", "wk", "wv", "wg", "wr", "up", "gate", "wk_up", "in_z", "in_x",
        "in_b", "in_c", "in_dt", "w2", "wkv_b"}
_ROW = {"wo", "down", "wv_down", "out_proj"}
_VEC = {"w_bias", "ln_w", "norm_w", "a_log", "dt_bias", "d_skip", "conv_x",
        "conv_b", "conv_c"}        # shard last dim (per-head vectors/convs)
_HEAD0 = {"u"}                     # (H, hd): shard dim -2
_VOCAB = {"embed", "lm_head"}      # (V, D): shard dim -2
_REPL = {"norm", "final_norm", "router", "wkv_a", "w1", "mu_r", "mu_k",
         "mu_v", "mu_g", "mu_w"}


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", p))) for p in path)


def _leaf_spec(path, leaf, axis: str = "model") -> P:
    names = _path_names(path)
    name = names[-1]
    nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    under_experts = "experts" in names

    def at(dim_from_end: int) -> P:
        spec = [None] * nd
        spec[nd - dim_from_end] = axis
        return P(*spec)

    if under_experts:
        # stacked (..., E, in, out): expert-parallel on the E dim
        return at(3)
    if name in _COL:
        return at(1)
    if name in _ROW:
        return at(2)
    if name in _VEC:
        return at(1)
    if name in _HEAD0:
        return at(2)
    if name in _VOCAB:
        return at(2)
    return P()  # replicated


def spec_has(spec: P, axis: str) -> bool:
    """True when `axis` appears in the PartitionSpec (P is a single pytree
    leaf, so jax.tree.leaves cannot be used to inspect it)."""
    for e in tuple(spec):
        if e == axis:
            return True
        if isinstance(e, (tuple, list)) and axis in e:
            return True
    return False


def param_pspecs(params_or_specs, axis: str = "model"):
    """PartitionSpec pytree matching the params structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_specs)
    specs = [_leaf_spec(path, leaf, axis) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# TP preparation (padding + replication) and masks
# ---------------------------------------------------------------------------

def prepare_params_for_tp(params, cfg: ModelConfig, tp: int):
    """Pad/replicate attention heads so all sharded dims divide ``tp``.

    Returns (prepared_params, masks) where masks is a pytree of {0,1}
    float multipliers freezing padded-head weights during training (None
    when no padding was needed).
    """
    plan = tp_head_plan(cfg.n_heads, cfg.n_kv_heads, tp)
    if not plan.padded:
        return params, None
    hd = cfg.head_dim
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out, masks = [], []
    for path, leaf in flat:
        names = _path_names(path)
        name = names[-1]
        is_mla = cfg.mla is not None
        new = leaf
        if not is_mla and name == "wq":
            new = _remap_cols(leaf, plan.q_map, hd, cfg.n_heads)
        elif not is_mla and name in ("wk", "wv") and "tmix" not in names:
            new = _remap_cols(leaf, plan.kv_map, hd, cfg.n_kv_heads)
        elif not is_mla and name == "wo" and "tmix" not in names:
            new = _remap_rows(leaf, plan.q_map, hd, cfg.n_heads)
        out.append(new)
        if new.shape == leaf.shape:
            masks.append(jnp.ones((), leaf.dtype))  # scalar -> broadcast
        else:
            masks.append(_pad_mask(new, leaf, name, plan, hd, cfg))
    prepared = jax.tree_util.tree_unflatten(treedef, out)
    mask_tree = jax.tree_util.tree_unflatten(treedef, masks)
    return prepared, mask_tree


def _pad_mask(new, old, name, plan: HeadPlan, hd, cfg):
    if name == "wq":
        keep = np.repeat(np.asarray([h >= 0 for h in plan.q_map]), hd)
        return jnp.asarray(keep, new.dtype)            # bcast over rows
    if name in ("wk", "wv"):
        keep = np.repeat(np.asarray([h >= 0 for h in plan.kv_map]), hd)
        return jnp.asarray(keep, new.dtype)
    if name == "wo":
        keep = np.repeat(np.asarray([h >= 0 for h in plan.q_map]), hd)
        return jnp.asarray(keep, new.dtype)[:, None]   # rows
    return jnp.ones((), new.dtype)


def apply_masks(tree, masks):
    """Re-zero padded head slots after an optimizer step (masks from
    prepare_params_for_tp; None = nothing padded)."""
    if masks is None:
        return tree
    return jax.tree.map(lambda t, m: t * m.astype(t.dtype), tree, masks)


def kv_replica_grad_sync(grads, cfg: ModelConfig, tp: int):
    """Average wk/wv gradients across replicas of the same original KV head.

    When tp > n_kv_heads the prepared layout replicates KV projections; each
    replica is a distinct slice of the padded weight and would receive a
    different gradient.  Averaging keeps replicas bit-identical (they start
    equal at preparation time), so training at high TP matches the unpadded
    model exactly.
    """
    plan = tp_head_plan(cfg.n_heads, cfg.n_kv_heads, tp)
    r = plan.kv_eff // max(cfg.n_kv_heads, 1)
    if not plan.padded or r <= 1 or cfg.mla is not None:
        return grads
    hd = cfg.head_dim

    def fix(path, g):
        name = _path_names(path)[-1]
        if name in ("wk", "wv") and g.shape[-1] == plan.kv_eff * hd:
            gs = g.reshape(*g.shape[:-1], cfg.n_kv_heads, r, hd)
            gs = jnp.broadcast_to(gs.mean(axis=-2, keepdims=True), gs.shape)
            return gs.reshape(g.shape)
        return g

    return jax.tree_util.tree_map_with_path(fix, grads)


# ---------------------------------------------------------------------------
# input/activation specs
# ---------------------------------------------------------------------------

def batch_pspec(pods: bool = True) -> P:
    """Global-batch inputs: sharded over (pod, data) on dim 0."""
    return P(("pod", "data")) if pods else P("data")
