"""Overlapped TP all-reduce: chunked ring collectives for the serving path.

The ladder residual exists to hide tensor-parallel communication, but a
monolithic ``jax.lax.psum`` leaves overlap entirely to XLA's scheduler.
This module provides the explicit alternative: the block-output AllReduce
is split into ``chunks`` independent ring reductions so chunk ``i``'s wire
time can hide under chunk ``i+1``'s compute (and, under the ladder
schedule, under the *next sub-block's* matmuls — see DESIGN.md
§Communication overlap).  Two wire formats:

``ring_all_reduce``
    full-precision chunked ring built on ``jax.lax.ppermute`` (the
    portable fallback; on TPU the Pallas async-remote-copy kernel in
    ``repro.kernels.comm`` implements the same schedule with explicit
    double-buffered DMA).

``compressed_ring_all_reduce``
    int8-on-wire variant (Flash-Communication style): each shard
    quantizes its local partial with :func:`repro.quant.quantize_int8`,
    the ring moves ``(q, scale)`` pairs (~2x fewer bytes than bf16), and
    every shard dequantizes and sums the images.  Bounded error, not
    bit-identical to the fp psum — see the error-bound property tests.

Determinism contract (load-bearing for the serving engines): every shard
sums the per-source contributions **in source-shard order** with the same
left-to-right association, so the result is bit-identical across shards at
any tp.  At tp=2 the sum is a single commutative IEEE add, hence bit-equal
to ``jax.lax.psum`` itself — which is what makes engine token streams
identical with overlap on vs off in the TP=2 tests.

``simulate_ring_all_reduce`` / ``simulate_compressed_all_reduce`` run the
same chunk schedule and summation order on a host-side ``(tp, ...)`` stack
of shard values; they are the fast-tier oracle (tests/test_collectives.py)
for the device path exercised under shard_map in the distributed suite.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.quant import dequantize_int8, dequantize_kv, quantize_int8, \
    quantize_kv

#: Valid values for :attr:`CommConfig.mode`, in dispatch order.
COMM_MODES = ("sync", "overlap", "compressed")


@dataclass(frozen=True)
class CommConfig:
    """How the block-output AllReduce executes (``AxisEnv.psum_model``).

    Frozen + hashable on purpose: an :class:`~repro.parallel.collectives.
    AxisEnv` carrying it is closed over by jit'ed step functions.

    mode
        ``sync``        one ``jax.lax.psum`` (XLA schedules any overlap)
        ``overlap``     chunked ppermute/DMA ring (:func:`ring_all_reduce`)
        ``compressed``  int8-on-wire ring (:func:`compressed_ring_all_reduce`)
    chunks
        ring chunk count; clamped to the element count per call site.
    fuse_norm
        defer the int8 wire's dequant-sum into the NEXT sub-block's RMSNorm
        (kernels/rmsnorm.rmsnorm_dequant): the ring delivers per-source
        quantized images (:func:`ring_block_images`) instead of a summed
        f32 activation, and the pre-norm read streams int8 instead of
        round-tripping f32 through HBM.  Requires ``mode="compressed"``
        (the images ARE the compressed wire format) and only engages on
        the ladder topology (the deferred pending is what a ladder carry
        already is; core/residual.py).
    """

    mode: str = "sync"
    chunks: int = 4
    fuse_norm: bool = False

    def __post_init__(self):
        if self.mode not in COMM_MODES:
            raise ValueError(
                f"invalid comm mode {self.mode!r}; expected one of {COMM_MODES}"
            )
        if self.chunks < 1:
            raise ValueError(f"comm chunks must be >= 1, got {self.chunks}")
        if self.fuse_norm and self.mode != "compressed":
            raise ValueError(
                "fuse_norm defers the int8 wire's dequant-sum into the next "
                "norm; it requires mode='compressed'")


#: Default configuration: the pre-existing synchronous psum behaviour.
SYNC = CommConfig()


def _static_axis_size(name) -> int:
    """Mesh-axis size as a *python int* (chunk loops are unrolled over it)."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(name))
    return int(jax.lax.psum(1, name))  # constant-folds on legacy jax


def chunk_bounds(n: int, chunks: int) -> list:
    """Static ``(start, size)`` spans splitting ``n`` elements into at most
    ``chunks`` near-equal pieces.

    The last chunk is ragged (possibly smaller), never empty; ``chunks`` is
    clamped to ``n`` so tiny activations degrade to fewer, non-empty chunks.
    """
    if n <= 0:
        return []
    chunks = max(1, min(chunks, n))
    size = -(-n // chunks)  # ceil
    return [(s, min(size, n - s)) for s in range(0, n, size)]


def _ring_contributions(c, axis_name, tp):
    """``(tp, *c.shape)`` stack of every shard's copy of this chunk, ordered
    by **source shard index** — identical ordering on every shard.

    Rotation ``s`` of the one-step ring permutation ``i -> (i+1) % tp``
    leaves shard ``i`` holding source ``(i - s) % tp``, so source ``j``
    lives at step ``(i - j) % tp``; the take() below inverts that.  The
    ``tp`` chunks' ppermute chains are independent, which is what lets XLA
    pipeline chunk ``k+1``'s hops under chunk ``k``'s consumer.
    """
    perm = [(i, (i + 1) % tp) for i in range(tp)]
    steps = [c]
    rot = c
    for _ in range(tp - 1):
        rot = jax.lax.ppermute(rot, axis_name, perm)
        steps.append(rot)
    by_step = jnp.stack(steps)
    idx = jax.lax.axis_index(axis_name)
    src_step = jnp.mod(idx - jnp.arange(tp), tp)
    return jnp.take(by_step, src_step, axis=0)


def _ordered_sum(contribs):
    """Left-to-right sum over the leading (source) axis — one fixed
    association so every shard (and the host simulator) rounds identically."""
    acc = contribs[0]
    for j in range(1, contribs.shape[0]):
        acc = acc + contribs[j]
    return acc


def ring_all_reduce(x, axis_name, *, chunks: int = 4):
    """Chunked ring AllReduce over ``axis_name`` (ppermute fallback path).

    Bit-identical across shards (source-ordered summation); bit-equal to
    ``jax.lax.psum`` at tp=2, within rounding at tp>2.  tp=1 is the
    documented degenerate path: returns ``x`` unchanged.

    On a TPU backend the same schedule runs as explicit double-buffered
    async remote-copy DMA (repro.kernels.comm); remote DMA has no
    cross-device interpret mode, so everywhere else uses the ppermute
    chain below.
    """
    tp = _static_axis_size(axis_name)
    if tp == 1:
        return x
    if jax.default_backend() == "tpu":
        from repro.kernels import comm as comm_kernels

        return comm_kernels.ring_all_reduce_remote(x, axis_name, chunks=chunks)
    flat = x.reshape(-1)
    pieces = []
    for start, size in chunk_bounds(flat.shape[0], chunks):
        c = flat[start:start + size]
        pieces.append(_ordered_sum(_ring_contributions(c, axis_name, tp)))
    return jnp.concatenate(pieces).reshape(x.shape)


def _dequant_add(acc, q, scale, size):
    """acc + dequantized first ``size`` elements of ``(q, scale)``.

    On TPU this is the fused Pallas masked dequant-accumulate kernel
    (repro.kernels.comm) — the mask keeps the quant-block pad tail out of
    the sum; elsewhere plain jnp (dequantize_int8 slices the pad off)."""
    if jax.default_backend() == "tpu":
        from repro.kernels import comm as comm_kernels

        return comm_kernels.dequant_accumulate(acc, q, scale, size)
    return acc + dequantize_int8(q, scale, (size,))


def compressed_ring_all_reduce(x, axis_name, *, chunks: int = 4):
    """int8-on-wire chunked ring AllReduce (quantize -> reduce -> dequantize).

    Each shard quantizes its local partial per chunk (256-element blocks,
    :func:`repro.quant.quantize_int8`), the ring rotates ``(q, scale)``
    pairs, and every shard dequantizes **all tp images — including its own
    quantized image** — and sums them in source order in f32.  Using the
    own *quantized* image (not the raw local value) keeps every shard's
    inputs bitwise identical, hence cross-shard bit-identity.

    Wire bytes ~ (1 + 4/256)/2 of the bf16 ring.  Per-element error is
    bounded by ``sum_j scale_j(block) / 2`` (each source contributes at
    most half a quant step); see tests/test_property.py.  NOT bit-identical
    to the fp psum — callers opt in per DESIGN.md §Communication overlap.
    """
    tp = _static_axis_size(axis_name)
    if tp == 1:
        return x  # degenerate: no wire traffic, no quantization error
    orig_dtype = x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    pieces = []
    for start, size in chunk_bounds(flat.shape[0], chunks):
        q, scale = quantize_int8(flat[start:start + size])
        qs = _ring_contributions(q, axis_name, tp)
        ss = _ring_contributions(scale, axis_name, tp)
        acc = jnp.zeros((size,), jnp.float32)
        for j in range(tp):
            acc = _dequant_add(acc, qs[j], ss[j], size)
        pieces.append(acc)
    return jnp.concatenate(pieces).reshape(x.shape).astype(orig_dtype)


# ---- deferred (fused-norm) wire format ------------------------------------

@jax.tree_util.register_dataclass
@dataclass
class PendingResidual:
    """A block-output AllReduce whose dequant-sum has NOT happened yet.

    The int8 wire format of :func:`compressed_ring_all_reduce`, kept as the
    per-source image stack instead of being summed on arrival: ``images``
    is ``(tp, ..., D)`` int8, ``scales`` ``(tp, ...)`` f32 — per-ROW
    symmetric quantization (:func:`repro.quant.quantize_kv` layout, one
    scale per (source, row)), so the ring's row-chunking never splits a
    quantization group.  Source-ordered like every ring in this module:
    every shard holds bit-identical stacks, so any shard's materialization
    (or fused-norm read) of the pending is bit-identical too.

    Consumed two ways, both summing sources left-to-right in f32:
    :meth:`materialize` (the jnp path — the ladder carry's residual
    update), and fused into the next sub-block's RMSNorm
    (kernels/rmsnorm.rmsnorm_dequant) so the pre-norm read streams int8.
    """

    images: jnp.ndarray   # (tp, ..., D) int8 per-source quantized images
    scales: jnp.ndarray   # (tp, ...)    f32 per-(source, row) scales

    def materialize(self, base):
        """``base + sum_j dequant(images[j])`` — f32 accumulation in source
        order (the association the fused kernel replays bit-for-bit)."""
        acc = base.astype(jnp.float32)
        for j in range(self.images.shape[0]):
            acc = acc + dequantize_kv(self.images[j], self.scales[j])
        return acc.astype(base.dtype)


def local_block_images(x) -> PendingResidual:
    """tp=1 degenerate of :func:`ring_block_images`: quantize the shard's
    OWN partial as a one-source stack.  Not the identity on purpose — the
    unsharded path exercises the same quantize -> deferred-dequant math the
    ring produces, so TP=1 tests pin the fused-norm numerics."""
    q, scale = quantize_kv(x)
    return PendingResidual(images=q[None], scales=scale[None])


def ring_block_images(x, axis_name, *, chunks: int = 4) -> PendingResidual:
    """Deferred AllReduce: rotate per-row int8 images around the ring and
    return the source-ordered stack WITHOUT summing.

    x: ``(..., D)`` partial block output.  Each shard quantizes per row
    (one scale per leading index, :func:`repro.quant.quantize_kv`), the
    ring moves ``(q, scale)`` pairs in row-aligned chunks (chunk ``k+1``'s
    hops pipeline under chunk ``k``'s consumer exactly like
    :func:`compressed_ring_all_reduce`), and the dequant-sum is left to the
    consumer — the next sub-block's fused RMSNorm on the serving decode
    path (DESIGN.md §Communication overlap).
    """
    tp = _static_axis_size(axis_name)
    lead, d = x.shape[:-1], x.shape[-1]
    q, scale = quantize_kv(x)
    q2, s2 = q.reshape(-1, d), scale.reshape(-1)
    if tp == 1:
        qs, ss = q2[None], s2[None]
    else:
        qp, sp = [], []
        for start, size in chunk_bounds(q2.shape[0], chunks):
            qp.append(_ring_contributions(q2[start:start + size],
                                          axis_name, tp))
            sp.append(_ring_contributions(s2[start:start + size],
                                          axis_name, tp))
        qs = jnp.concatenate(qp, axis=1)
        ss = jnp.concatenate(sp, axis=1)
    return PendingResidual(images=qs.reshape(tp, *lead, d),
                           scales=ss.reshape(tp, *lead))


# ---- host-side simulators (fast-tier oracles) -----------------------------

def _simulated_contributions(flat, i, start, size, tp):
    """Mirror of :func:`_ring_contributions` for shard ``i`` on a host-side
    ``(tp, n)`` stack: build the by-step buffer the ring would hold, then
    apply the same source-order take()."""
    by_step = jnp.stack(
        [flat[(i - s) % tp, start:start + size] for s in range(tp)]
    )
    src_step = jnp.mod(i - jnp.arange(tp), tp)
    return jnp.take(by_step, src_step, axis=0)


def simulate_ring_all_reduce(shards, *, chunks: int = 4):
    """Run :func:`ring_all_reduce`'s exact chunk schedule and summation
    order on a stacked ``(tp, ...)`` host array; returns the per-shard
    results stacked the same way (all rows bit-identical by construction)."""
    shards = jnp.asarray(shards)
    tp = shards.shape[0]
    flat = shards.reshape(tp, -1)
    outs = []
    for i in range(tp):
        pieces = []
        for start, size in chunk_bounds(flat.shape[1], chunks):
            contribs = _simulated_contributions(flat, i, start, size, tp)
            pieces.append(_ordered_sum(contribs))
        outs.append(jnp.concatenate(pieces))
    return jnp.stack(outs).reshape(shards.shape)


def simulate_ring_block_images(shards) -> PendingResidual:
    """Host-side mirror of :func:`ring_block_images` over a ``(tp, ..., D)``
    stack of shard partials.  Source ordering makes every shard's stacks
    identical, so the simulated result is simply each shard's own
    quantized image stacked in source order — the oracle the distributed
    suite checks the device ring against."""
    shards = jnp.asarray(shards)
    q, scale = quantize_kv(shards)
    return PendingResidual(images=q, scales=scale)


def simulate_compressed_all_reduce(shards, *, chunks: int = 4):
    """Host-side mirror of :func:`compressed_ring_all_reduce` over a
    ``(tp, ...)`` stack of shard values."""
    shards = jnp.asarray(shards)
    tp = shards.shape[0]
    orig_dtype = shards.dtype
    flat = shards.astype(jnp.float32).reshape(tp, -1)
    n = flat.shape[1]
    quants = {}
    for start, size in chunk_bounds(n, chunks):
        quants[start] = [quantize_int8(flat[j, start:start + size])
                         for j in range(tp)]
    outs = []
    for i in range(tp):
        pieces = []
        for start, size in chunk_bounds(n, chunks):
            q_stack = jnp.stack([quants[start][j][0] for j in range(tp)])
            s_stack = jnp.stack([quants[start][j][1] for j in range(tp)])
            qs = _simulated_contributions(q_stack, i, 0, q_stack.shape[1], tp)
            ss = _simulated_contributions(s_stack, i, 0, s_stack.shape[1], tp)
            acc = jnp.zeros((size,), jnp.float32)
            for j in range(tp):
                acc = acc + dequantize_int8(qs[j], ss[j], (size,))
            pieces.append(acc)
        outs.append(jnp.concatenate(pieces))
    return jnp.stack(outs).reshape(shards.shape).astype(orig_dtype)
