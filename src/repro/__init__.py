"""repro: Ladder-Residual (ICML 2025) reproduction — a multi-pod JAX
training/inference framework with communication-overlapping residual
topologies as a first-class feature."""

__version__ = "1.0.0"
