"""Input specs (ShapeDtypeStruct stand-ins) for every (arch x shape) cell.

No device allocation happens here: training batches, serving caches and
parameters are all described as shape/dtype structs that the dry-run lowers
against, exactly like weak-type-correct tracing inputs.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.serving import engine


def plan_parallel(cfg: ModelConfig, shape: ShapeConfig,
                  multi_pod: bool = False) -> ParallelConfig:
    """Production parallelism for one cell on the (16,16)/(2,16,16) mesh."""
    pods = 2 if multi_pod else 1
    dp, tp = 16, 16
    shard_seq = shape.kind == "decode" and shape.global_batch < dp * pods
    return ParallelConfig(tp=tp, dp=dp, pods=pods,
                          shard_seq_for_decode=shard_seq)


def dec_seq(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Decoder-token sequence length for one cell (enc-dec and VLM archs
    consume part of the cell's seq_len with frontend positions)."""
    if cfg.encoder_layers:
        return shape.seq_len // cfg.encoder_seq_ratio
    return shape.seq_len


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if cfg.encoder_layers:
        sd = dec_seq(cfg, shape)
        return dict(tokens=jax.ShapeDtypeStruct((b, sd), i32),
                    targets=jax.ShapeDtypeStruct((b, sd), i32),
                    frames=jax.ShapeDtypeStruct((b, s, cfg.d_model), dt))
    if cfg.family == "vlm":
        st = s - cfg.num_patches
        return dict(tokens=jax.ShapeDtypeStruct((b, st), i32),
                    targets=jax.ShapeDtypeStruct((b, st), i32),
                    patches=jax.ShapeDtypeStruct((b, cfg.num_patches,
                                                  cfg.d_model), dt))
    return dict(tokens=jax.ShapeDtypeStruct((b, s), i32),
                targets=jax.ShapeDtypeStruct((b, s), i32))


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                      pcfg: ParallelConfig):
    """Returns (tokens_struct, cache_structs, extra_structs, cache_pspecs)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    seq_shard = pcfg.shard_seq_for_decode
    sd = dec_seq(cfg, shape)

    if shape.kind == "prefill":
        caches, cache_specs = engine.build_caches(
            cfg, b, s if not cfg.encoder_layers else sd, pcfg,
            for_decode=False, structs_only=True)
        extra = {}
        if cfg.encoder_layers:
            extra["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            tok = jax.ShapeDtypeStruct((b, sd), i32)
        elif cfg.family == "vlm":
            extra["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), dt)
            tok = jax.ShapeDtypeStruct((b, s - cfg.num_patches), i32)
        else:
            tok = jax.ShapeDtypeStruct((b, s), i32)
        return tok, caches, extra, cache_specs

    # decode: one new token against a cache of seq_len
    caches, cache_specs = engine.build_caches(
        cfg, b, sd if cfg.encoder_layers else s, pcfg, for_decode=True,
        seq_shard_data=seq_shard, enc_s=s if cfg.encoder_layers else 0,
        structs_only=True)
    tok = jax.ShapeDtypeStruct((b,), i32)
    return tok, caches, {}, cache_specs
