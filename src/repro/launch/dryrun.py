import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count on first init).  Smoke tests and benches never import this module —
they see 1 device.

For every cell this driver:
  1. builds the production mesh ((16,16) or (2,16,16)),
  2. plans parallelism (TP=16, DP=16, pod = extra DP; long-context decode
     shards the KV sequence over the data axes),
  3. lowers + compiles the train_step / prefill / serve_step against
     ShapeDtypeStruct inputs (no allocation),
  4. prints memory_analysis() (proves per-device fit) and cost_analysis(),
  5. derives the three roofline terms (launch/roofline.py) and appends the
     row to a JSON results file consumed by EXPERIMENTS.md and benchmarks.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-70b \
      --shape train_4k --mesh both --residual ladder
  PYTHONPATH=src python -m repro.launch.dryrun --all   # 40-cell baseline
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import (ALL_SHAPES, ASSIGNED_ARCHS, SHAPES_BY_NAME,
                           TrainConfig, get_config)
from repro.launch import roofline as rl
from repro.parallel import compat
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (dec_seq, plan_parallel, serve_input_specs,
                                train_input_specs)
from repro.models import transformer as tfm
from repro.models.model import count_params, model_flops
from repro.parallel import sharding
from repro.parallel import tp as tpmod
from repro.serving import engine

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun.json"


def _train_structs(cfg, pcfg, fsdp=True):
    return jax.eval_shape(
        lambda: tpmod.init_train_state(cfg, pcfg, jax.random.key(0),
                                       fsdp=fsdp)[:2])


def _serve_param_structs(cfg, pcfg, fsdp=False, fsdp_q8=False):
    def mk():
        p = tfm.init_params(cfg, jax.random.key(0))
        p, _ = sharding.prepare_params_for_tp(p, cfg, pcfg.tp)
        if fsdp:
            from repro.parallel import fsdp as fsdp_mod
            sec = sharding.param_pspecs(p)["sections"]
            if fsdp_q8:
                flat = fsdp_mod.flatten_sections_host_q8(
                    p["sections"], sec, pcfg.tp, pcfg.dp)
            else:
                flat, _ = fsdp_mod.flatten_sections_host(
                    p["sections"], sec, pcfg.tp, pcfg.dp)
            p = dict(p)
            p["sections"] = flat
        return p
    return jax.eval_shape(mk)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             residual: str = "ladder", *, verbose: bool = True,
             use_sp: bool = False, extra_tag: str = "",
             overrides: dict | None = None) -> dict:
    cfg_overrides = {k: v for k, v in (overrides or {}).items()
                     if not k.startswith("_")}
    cfg = get_config(arch, residual=residual, **cfg_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}/{shape_name}/{mesh_name}/{residual}{extra_tag}"

    if shape_name not in cfg.supported_shapes:
        return dict(cell=tag, status="skipped",
                    reason="unsupported shape for this arch family "
                           "(DESIGN.md §Arch-applicability)")

    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = plan_parallel(cfg, shape, multi_pod)
    if use_sp:
        import dataclasses
        pcfg = dataclasses.replace(pcfg, use_sp=True)
    chips = pcfg.world
    t0 = time.time()

    if shape.kind == "train":
        # microbatch so each device sees ONE sequence per micro-step —
        # bounds activation memory (remat checkpoints scale with the
        # per-micro token count)
        per_dev = shape.global_batch // (pcfg.dp * pcfg.pods)
        tcfg = TrainConfig(grad_accum=max(1, per_dev))
        step, in_specs, _ = tpmod.build_train_step(cfg, mesh, pcfg, tcfg,
                                                   fsdp=True)
        params_s, opt_s = _train_structs(cfg, pcfg, fsdp=True)
        batch_s = train_input_specs(cfg, shape)
        with compat.set_mesh(mesh):
            # donate params + opt state: updated in place on real hardware
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_s, opt_s, batch_s, jax.ShapeDtypeStruct((), jnp.int32))
        mf = model_flops(cfg, shape.tokens, train=True)
    else:
        fsdp = engine.serve_needs_fsdp(cfg, pcfg)
        fsdp_q8 = fsdp and bool((overrides or {}).get("_serve_q8"))
        steps = engine.build_serve_steps(
            cfg, mesh, pcfg, seq_shard_data=pcfg.shard_seq_for_decode,
            fsdp=fsdp, fsdp_q8=fsdp_q8)
        tok_s, cache_s, extra_s, cache_specs = serve_input_specs(cfg, shape,
                                                                 pcfg)
        params_s = _serve_param_structs(cfg, pcfg, fsdp=fsdp,
                                        fsdp_q8=fsdp_q8)
        if shape.kind == "prefill":
            sd = dec_seq(cfg, shape)
            out_cache_specs = engine.build_caches(
                cfg, shape.global_batch,
                sd if cfg.encoder_layers else shape.seq_len, pcfg,
                for_decode=True,
                enc_s=shape.seq_len if cfg.encoder_layers else 0,
                structs_only=True)[1]
            fn = engine.shard_mapped(
                steps["prefill"], mesh,
                (steps["pspecs"], steps["tok_spec"], cache_specs,
                 {k: steps["tok_spec"] for k in extra_s}),
                (out_cache_specs, steps["tok_spec"]))
            args = (params_s, tok_s, cache_s, extra_s)
            mf = model_flops(cfg, shape.tokens, train=False)
        else:
            fn = engine.shard_mapped(
                steps["decode"], mesh,
                (steps["pspecs"], steps["tok_spec"], cache_specs, P()),
                (cache_specs, steps["tok_spec"]))
            args = (params_s, tok_s, cache_s,
                    jax.ShapeDtypeStruct((), jnp.int32))
            mf = model_flops(cfg, shape.global_batch, train=False,
                             decode_context=shape.seq_len)
        with compat.set_mesh(mesh):
            # donate the KV caches: updated in place on real hardware
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(*args)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof = rl.analyse(arch, shape_name, mesh_name, compiled, mf, chips,
                      hlo_text=hlo)
    row = roof.row()
    row.update(cell=tag, status="ok", t_lower_s=round(t_lower, 2),
               t_compile_s=round(t_compile, 2),
               residual=residual,
               params=count_params(cfg),
               mem=dict(argument=ma.argument_size_in_bytes,
                        output=ma.output_size_in_bytes,
                        temp=ma.temp_size_in_bytes))
    if verbose:
        per_dev_gb = (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 1e9
        print(f"[{tag}] compile={t_compile:.1f}s "
              f"mem/dev={per_dev_gb:.2f}GB "
              f"t_comp={roof.t_compute*1e3:.2f}ms t_mem={roof.t_memory*1e3:.2f}ms "
              f"t_coll={roof.t_collective*1e3:.2f}ms -> {roof.bottleneck} "
              f"useful={roof.useful_ratio:.2f} roofline={roof.roofline_fraction:.3f}")
        print(f"  memory_analysis: {ma}")
        ca = compiled.cost_analysis() or {}
        print(f"  cost_analysis: flops={ca.get('flops')} "
              f"bytes={ca.get('bytes accessed')}")
    return row


def append_result(row: dict, path: Path = RESULTS):
    path.parent.mkdir(parents=True, exist_ok=True)
    data = []
    if path.exists():
        data = json.loads(path.read_text())
    data = [r for r in data if r.get("cell") != row.get("cell")]
    data.append(row)
    path.write_text(json.dumps(data, indent=1, default=float))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--residual", default="ladder")
    ap.add_argument("--all", action="store_true",
                    help="all 40 assigned cells (single-pod baseline)")
    ap.add_argument("--multi-all", action="store_true",
                    help="all 40 assigned cells on the 2x16x16 mesh")
    ap.add_argument("--sp", action="store_true", help="sequence parallelism")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    out = Path(args.out)
    cells = []
    if args.all or args.multi_all:
        for arch in ASSIGNED_ARCHS:
            for shape in ALL_SHAPES:
                cells.append((arch, shape.name, args.multi_all))
    else:
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        try:
            row = run_cell(arch, shape, mp, residual=args.residual,
                           use_sp=args.sp,
                           extra_tag="+sp" if args.sp else "")
            append_result(row, out)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            traceback.print_exc()
            append_result(dict(
                cell=f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}/{args.residual}",
                status="error", error=f"{type(e).__name__}: {e}"), out)
    print(f"done; failures={failures}; results -> {out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
