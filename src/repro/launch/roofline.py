"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), all per-device, in seconds:

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes / HBM_bw
  collective = sum_i ring_bytes_i / link_bw

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

The CPU backend's ``compiled.cost_analysis()`` counts while-loop bodies ONCE
(scan-over-layers would be undercounted ~n_layers x), so we parse
``compiled.as_text()`` ourselves:

* every computation gets a symbol table (op name -> result shapes);
* a DFS from ENTRY accumulates (flops, bytes, collective bytes), multiplying
  while bodies by the ``known_trip_count`` XLA records in backend_config
  (nested loops — grad-accumulation over a layer scan — multiply through);
* dot FLOPs = 2 * |result| * |contracted dims| (resolved via the symbol
  table); fusions are recursed for FLOPs but charged operand+result bytes
  only (fusion internals live in registers/VMEM — the TPU traffic model);
* collectives are weighted by ring cost for their replica-group size
  (all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all (n-1)/n,
  collective-permute 1).

``cost_analysis()`` numbers are retained in the report as a cross-check.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


def kernel_time_bound_s(bytes_read: float, flops: float) -> float:
    """Roofline lower bound on one kernel invocation: it can finish no
    faster than its HBM stream or its FLOPs, whichever dominates.  The
    kernel autotuner (kernels/autotune.py) uses this as a sanity check on
    sweep winners — a measured time BELOW this bound is measurement noise
    (a cached result, a clock glitch), not a real tuning, and is
    rejected."""
    return max(bytes_read / HBM_BW, flops / PEAK_FLOPS)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\{\{(\d+(?:,\d+)*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_CALL_LIST_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "iota", "after-all", "partition-id", "replica-id",
               "opt-barrier"}
# Ops that materialise buffers on TPU.  Everything else (raw elementwise,
# convert, broadcast, compare, select, ...) is assumed fused into a
# neighbouring op by the TPU backend — the CPU HLO we parse is less fused
# than TPU HLO would be, so charging unfused elementwise ops would inflate
# the memory term several-fold.
_TRAFFIC = {"dot", "convolution", "fusion", "call", "conditional",
            "custom-call", "copy", "dynamic-slice", "dynamic-update-slice",
            "slice", "reduce", "reduce-window", "transpose", "scatter",
            "gather", "concatenate", "pad", "reverse", "sort", "rng",
            "cholesky", "triangular-solve"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclass
class Op:
    name: str
    kind: str
    rtype: str          # raw result-type text
    operands: List[str]
    attrs: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.rtype)


def _parse_operands(rest: str) -> Tuple[List[str], str]:
    """Split 'a, %b, ...), attr=...' into operand names and attrs."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner, attrs = rest[:i], rest[i + 1:]
                names = re.findall(r"%([\w\.\-]+)", inner)
                return names, attrs
    return re.findall(r"%([\w\.\-]+)", rest), ""


def parse_computations(hlo: str) -> Tuple[Dict[str, List[Op]], str]:
    """Returns ({computation -> ops}, entry_name)."""
    comps: Dict[str, List[Op]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        # computation headers start at column 0 and end with the opening
        # brace; everything else (HloModule line, stack-frame trailer,
        # in-computation ops) fails one of the two conditions.
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, kind, rest = m.groups()
        operands, attrs = _parse_operands(rest)
        comps[cur].append(Op(name, kind, rtype, operands, attrs))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_count: int = 0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    copy_bytes: float = 0.0   # raw `copy` op traffic — CPU-backend copies
                              # that donation/in-place DUS removes on TPU

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.coll_count += o.coll_count
        self.copy_bytes += o.copy_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    int(self.coll_count * m),
                    {k: v * m for k, v in self.coll_by_kind.items()},
                    self.copy_bytes * m)


def _dot_flops(op: Op, table: Dict[str, str]) -> float:
    res = _shape_dims(op.rtype)
    relems = 1
    for _, dims in res:
        for d in dims:
            relems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    lhs_ct = [int(x) for x in m.group(1).split(",") if x] if m else []
    lhs_type = table.get(op.operands[0], "") if op.operands else ""
    lhs = _shape_dims(lhs_type)
    contracted = 1
    if lhs:
        dims = lhs[0][1]
        for i in lhs_ct:
            if i < len(dims):
                contracted *= dims[i]
    return 2.0 * relems * contracted


def _group_size(attrs: str) -> int:
    gm = _GROUPS_RE.search(attrs)
    if gm:
        return len(gm.group(1).split(","))
    gi = _GROUPS_IOTA_RE.search(attrs)
    if gi:
        return int(gi.group(2))
    return 1


def _called_comps(attrs: str) -> List[str]:
    names = list(_CALL_RE.findall(attrs))
    for blob in _CALL_LIST_RE.findall(attrs):
        names += [n.strip().lstrip("%") for n in blob.split(",") if n.strip()]
    return names


def _ring_weight(kind: str, n: int) -> float:
    if kind == "all-reduce":
        return 2 * (n - 1) / max(n, 1)
    if kind in ("all-gather", "reduce-scatter", "all-to-all",
                "ragged-all-to-all"):
        return (n - 1) / max(n, 1)
    return 1.0  # collective-permute


def analyse_hlo(hlo: str) -> Cost:
    comps, entry = parse_computations(hlo)
    tables: Dict[str, Dict[str, str]] = {
        cname: {op.name: op.rtype for op in ops}
        for cname, ops in comps.items()}
    memo: Dict[str, Cost] = {}

    def cost_of(cname: str) -> Cost:
        if cname in memo:
            return memo[cname]
        memo[cname] = Cost()          # break cycles defensively
        total = Cost()
        table = tables.get(cname, {})
        for op in comps.get(cname, []):
            kind = op.kind
            if kind.endswith("-done"):
                continue
            base_kind = kind[:-6] if kind.endswith("-start") else kind
            if base_kind == "while":
                m = _TRIP_RE.search(op.attrs)
                trips = int(m.group(1)) if m else 1
                sub = Cost()
                for n in _called_comps(op.attrs):
                    sub += cost_of(n)
                total += sub.scaled(trips)
                continue
            if base_kind in ("fusion", "call", "conditional",
                             "custom-call"):
                inner_bytes = 0.0
                has_dus = False
                pure_elementwise = True
                for n in _called_comps(op.attrs):
                    sub = cost_of(n)
                    inner_bytes += sub.bytes
                    for o in comps.get(n, []):
                        if o.kind == "dynamic-update-slice":
                            has_dus = True
                        if o.kind in _TRAFFIC or o.kind in _COLLECTIVES:
                            pure_elementwise = False
                    total += Cost(sub.flops, 0.0, sub.coll_bytes,
                                  sub.coll_count, dict(sub.coll_by_kind))
                # Fusion traffic model: internals use the same op rules
                # (slices charge slice-sized bytes); the boundary streams
                # at most result-sized reads per operand plus the output
                # write.  In-place update fusions (DUS root) write only the
                # updated region, already charged by the internal rule.
                # Pure-elementwise fusions (the CPU backend wraps EVERY
                # elementwise op in its own kLoop fusion) charge nothing:
                # on TPU these fuse into their producers/consumers.
                if pure_elementwise and not has_dus:
                    pass
                elif has_dus:
                    total += Cost(0.0, inner_bytes, 0.0, 0)
                else:
                    res = op.result_bytes
                    opb = sum(min(_shape_bytes(table.get(o, "")), res)
                              for o in op.operands)
                    total += Cost(0.0, inner_bytes + opb + res, 0.0, 0)
                continue
            if base_kind in _COLLECTIVES:
                n = _group_size(op.attrs)
                full = op.result_bytes
                if base_kind == "reduce-scatter":
                    full *= n
                w = _ring_weight(base_kind, n)
                opb = sum(_shape_bytes(table.get(o, ""))
                          for o in op.operands)
                total += Cost(0.0, opb + op.result_bytes, full * w, 1,
                              {base_kind: full * w})
                continue
            if base_kind in _NO_TRAFFIC:
                continue
            flops = 0.0
            if base_kind == "dot":
                flops = _dot_flops(op, table)
            if base_kind in ("slice", "dynamic-slice", "gather"):
                # reads + writes only the slice, not the source buffer
                total += Cost(flops, 2 * op.result_bytes, 0.0, 0)
            elif base_kind == "dynamic-update-slice":
                upd = _shape_bytes(table.get(op.operands[1], "")) \
                    if len(op.operands) > 1 else op.result_bytes
                total += Cost(flops, 2 * upd, 0.0, 0)
            elif base_kind == "scatter":
                upd = _shape_bytes(table.get(op.operands[2], "")) \
                    if len(op.operands) > 2 else op.result_bytes
                total += Cost(flops, 3 * upd, 0.0, 0)
            elif base_kind in _TRAFFIC:
                opb = sum(_shape_bytes(table.get(o, ""))
                          for o in op.operands)
                cb = opb + op.result_bytes if base_kind == "copy" else 0.0
                total += Cost(flops, opb + op.result_bytes, 0.0, 0,
                              copy_bytes=cb)
            else:
                total += Cost(flops, 0.0, 0.0, 0)
        memo[cname] = total
        return total

    return cost_of(entry) if entry else Cost()


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops_global: float
    chips: int
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    n_collectives: int = 0
    coll_by_kind: dict = field(default_factory=dict)
    ca_flops: float = 0.0           # raw cost_analysis (loop bodies x1)
    ca_bytes: float = 0.0
    copy_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_memory_nocopy(self) -> float:
        """Memory term excluding raw copies — the TPU number (donation +
        in-place dynamic-update-slice removes them; the CPU backend we
        compile on inserts copies the TPU backend would alias away)."""
        return max(self.bytes_per_dev - self.copy_bytes, 0.0) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / LINK_BW

    @property
    def bottleneck(self) -> str:
        ts = dict(compute=self.t_compute, memory=self.t_memory,
                  collective=self.t_collective)
        return max(ts, key=ts.get)

    @property
    def useful_ratio(self) -> float:
        hlo_global = self.flops_per_dev * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOP fraction of peak, assuming perfect overlap: the step
        cannot run faster than max(t_compute, t_memory, t_collective)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t == 0:
            return 0.0
        return (self.model_flops_global / self.chips) / (PEAK_FLOPS * t)

    def row(self) -> dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            model_flops=self.model_flops_global,
            hlo_flops_per_dev=self.flops_per_dev,
            hlo_bytes_per_dev=self.bytes_per_dev,
            coll_bytes_per_dev=self.coll_bytes_per_dev,
            coll_by_kind=self.coll_by_kind,
            useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
            t_memory_nocopy=self.t_memory_nocopy,
            copy_bytes=self.copy_bytes,
            temp_bytes=self.temp_bytes, argument_bytes=self.argument_bytes,
            n_collectives=self.n_collectives,
            ca_flops=self.ca_flops, ca_bytes=self.ca_bytes)


def analyse(arch, shape, mesh_name, compiled, model_flops_global, chips,
            hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = hlo_text if hlo_text is not None else compiled.as_text()
    cost = analyse_hlo(hlo)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_dev=cost.flops,
        bytes_per_dev=cost.bytes,
        coll_bytes_per_dev=cost.coll_bytes,
        model_flops_global=model_flops_global, chips=chips,
        argument_bytes=getattr(ma, "argument_size_in_bytes", 0),
        output_bytes=getattr(ma, "output_size_in_bytes", 0),
        temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
        n_collectives=cost.coll_count,
        coll_by_kind=dict(cost.coll_by_kind),
        ca_flops=float(ca.get("flops", 0.0)),
        ca_bytes=float(ca.get("bytes accessed", 0.0)),
        copy_bytes=cost.copy_bytes)
