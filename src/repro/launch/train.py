"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch ladder-1b \
      --residual ladder --steps 300 --tp 2 --dp 2 \
      --reduced --ckpt /tmp/run1

On the production pod this launches with tp=16/dp=16 (and --pods 2) over the
real mesh; on this CPU container use --devices to fake a small mesh.
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ladder-1b")
    ap.add_argument("--residual", default="ladder")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N fake host devices (CPU testing)")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) config of the family")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=8)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    from repro.configs import TrainConfig, ParallelConfig, get_config
    from repro.launch.mesh import make_mesh_for
    from repro.training.data import SyntheticLM
    from repro.training.trainer import Trainer

    cfg = get_config(args.arch, residual=args.residual)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.n_layers, d_model=args.d_model,
                          n_heads=max(4, args.d_model // 64),
                          d_ff=args.d_model * 4, vocab_size=2048)
    pcfg = ParallelConfig(tp=args.tp, dp=args.dp, pods=args.pods)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=args.warmup,
                       total_steps=args.steps,
                       checkpoint_every=args.ckpt_every)
    mesh = make_mesh_for(pcfg.world, args.tp, args.pods)
    loader = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)
    trainer = Trainer(cfg, mesh, pcfg, tcfg, ckpt_dir=args.ckpt,
                      zero1=args.zero1, fsdp=args.fsdp)
    state = trainer.resume_or_init()
    state = trainer.fit(state, loader, args.steps - state.step)
    print(f"done at step {state.step}")


if __name__ == "__main__":
    main()
