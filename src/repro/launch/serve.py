"""Serving driver: load (or init) a model, prefill a batch of prompts,
decode N tokens greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch ladder-1b \
      --residual ladder --reduced --prompt-len 64 --gen 32 --batch 4
"""

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ladder-1b")
    ap.add_argument("--residual", default="ladder")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import ParallelConfig, get_config
    from repro.launch.mesh import make_mesh_for
    from repro.models import transformer as tfm
    from repro.parallel import sharding
    from repro.serving import engine
    from repro.training.checkpoint import CheckpointManager

    cfg = get_config(args.arch, residual=args.residual)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=256, n_heads=4, d_ff=512,
                          vocab_size=2048)
    pcfg = ParallelConfig(tp=args.tp, dp=args.dp)
    mesh = make_mesh_for(pcfg.world, args.tp)

    params = tfm.init_params(cfg, jax.random.key(0))
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt)
        _, params, _, _ = mgr.restore(params)
        print(f"[serve] restored step {mgr.latest_step()}")
    params, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)

    b = args.batch
    s_max = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.key(1), (b, args.prompt_len),
                                 0, cfg.vocab_size)
    caches, cache_specs = engine.build_caches(cfg, b, s_max, pcfg,
                                              for_decode=False)
    steps = engine.build_serve_steps(cfg, mesh, pcfg)
    out_cache_specs = engine.build_caches(cfg, b, s_max, pcfg,
                                          for_decode=True,
                                          structs_only=True)[1]
    prefill = engine.shard_mapped(
        steps["prefill"], mesh,
        (steps["pspecs"], steps["tok_spec"], cache_specs, {}),
        (out_cache_specs, steps["tok_spec"]))
    decode = engine.shard_mapped(
        steps["decode"], mesh,
        (steps["pspecs"], steps["tok_spec"], out_cache_specs, P()),
        (out_cache_specs, steps["tok_spec"]))

    with jax.set_mesh(mesh):
        t0 = time.time()
        caches, tok = jax.jit(prefill)(params, prompts, caches, {})
        tok.block_until_ready()
        t_prefill = time.time() - t0
        gen = [tok]
        jd = jax.jit(decode, donate_argnums=(2,))
        t0 = time.time()
        for i in range(args.gen - 1):
            caches, tok = jd(params, tok, caches,
                             jnp.asarray(args.prompt_len + i, jnp.int32))
            gen.append(tok)
        tok.block_until_ready()
        t_decode = time.time() - t0

    toks = jnp.stack(gen, axis=1)
    print(f"[serve] prefill {args.prompt_len} toks x{b}: {t_prefill*1e3:.1f}ms")
    print(f"[serve] decode {args.gen - 1} steps: {t_decode*1e3:.1f}ms "
          f"({(args.gen - 1) * b / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] sample output ids: {toks[0][:16].tolist()}")


if __name__ == "__main__":
    main()
