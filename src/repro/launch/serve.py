"""Serving driver: continuous batching over paged or ragged KV caches.

Loads (or inits) a model, submits a stream of variable-length synthetic
requests, and serves them through a continuous-batching engine
(serving/scheduler.py): prefill of newly admitted requests interleaves with
batched decode of in-flight ones, retired slots are refilled from the queue,
and every request samples with its own temperature / top-k / top-p / seed.

The default engine is the paged-KV path (block-pool caches, block-granular
admission, chunked prefill, prefix reuse — DESIGN.md §Paged KV); families or
shardings the paged path does not cover yet fall back to the PR-1 ragged
engine automatically (``--engine ragged`` forces it; ``--engine paged``
errors instead of falling back).

  PYTHONPATH=src python -m repro.launch.serve --arch ladder-1b \
      --residual ladder --reduced --slots 4 --requests 12 --gen 32
"""

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ladder-1b")
    ap.add_argument("--residual", default="ladder")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "paged", "ragged"],
                    help="KV layout: paged block pool (default when "
                         "supported) or the ragged per-slot oracle")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slot pool size (max concurrent requests)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged engine: physical pool size "
                         "(0 = slots * ceil(s_max/block_size))")
    ap.add_argument("--prefill-budget", type=int, default=128,
                    help="paged engine: max prompt tokens prefilled per "
                         "engine step (chunked prefill)")
    ap.add_argument("--kv-quant", default="fp", choices=["fp", "int8"],
                    help="paged engine KV storage: int8 stores the block "
                         "pool quantized with per-(token, head) scales — "
                         "~2x+ the rows per pool byte and ~2x less decode "
                         "read traffic; tokens may differ from fp within a "
                         "bounded logit error (DESIGN.md §KV memory tiers)")
    ap.add_argument("--oversubscribe", type=float, default=1.0,
                    help="paged engine: admit decode reservations against "
                         "this multiple of the physical pool; > 1 enables "
                         "preemption — on pressure the lowest-priority "
                         "decoding row swaps out to the host tier and "
                         "resumes verbatim (bit-identical tokens)")
    ap.add_argument("--swap-blocks", type=int, default=0,
                    help="host swap tier capacity in blocks (0 = "
                         "unbounded).  Only bounds the tier — preemption "
                         "itself engages only under --oversubscribe > 1 "
                         "(without it every reservation is physically "
                         "backed and the pool can never run dry)")
    ap.add_argument("--comm-overlap", action="store_true",
                    help="paged engine: run the TP block-output AllReduce "
                         "as a chunked overlapped ring (Pallas remote-copy "
                         "on TPU, ppermute elsewhere) instead of one "
                         "synchronous psum; token streams are "
                         "bit-identical at TP<=2 (parallel/overlap.py, "
                         "DESIGN.md §Communication overlap)")
    ap.add_argument("--comm-quant", action="store_true",
                    help="paged engine: int8-compress the TP AllReduce "
                         "wire (quantize -> ring-reduce -> dequantize, "
                         "~2x fewer bytes); bounded activation error, NOT "
                         "bit-identical to the fp psum.  Implies the ring "
                         "(wins over --comm-overlap)")
    ap.add_argument("--comm-chunks", type=int, default=4,
                    help="ring chunk count for --comm-overlap/--comm-quant "
                         "(chunk i's hops pipeline under chunk i+1)")
    ap.add_argument("--comm-fuse-norm", action="store_true",
                    help="paged engine, ladder only: defer the int8 "
                         "AllReduce's dequant-sum into the next sub-block's "
                         "RMSNorm (fused Pallas dequant+norm kernel under "
                         "--use-pallas) — the pre-norm read streams int8 "
                         "instead of round-tripping f32 through HBM.  "
                         "Implies --comm-quant's wire; bounded error like "
                         "it (DESIGN.md §Communication overlap)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="run attention through the Pallas kernels: the "
                         "paged engine reads the KV pool with the "
                         "block-table-native paged-attention kernel "
                         "(bytes-read tracks each row's actual kv length); "
                         "tokens are bit-identical to the default gather "
                         "path.  Compiled on TPU, interpret mode elsewhere")
    ap.add_argument("--autotune", action="store_true",
                    help="re-sweep the paged-kernel launch geometry for "
                         "this host before serving (kernels/autotune.py) "
                         "and consult the fresh table; without it the "
                         "committed results/kernel_tuning.json is used, "
                         "with deterministic defaults on a missing key")
    ap.add_argument("--no-tune", action="store_true",
                    help="ignore the tuning table: run the paged kernel "
                         "with the deterministic default launch geometry "
                         "(tokens are bit-identical either way)")
    ap.add_argument("--spec-decode", default="off",
                    choices=["off", "ngram", "draft"],
                    help="speculative decoding on the paged engine: ngram "
                         "(prompt-lookup self-speculation) or draft (small "
                         "draft transformer); output tokens are "
                         "bit-identical to off")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens verified per step")
    ap.add_argument("--draft-layers", type=int, default=2,
                    help="--spec-decode draft: layer count of the "
                         "config-derived draft model (same arch, reduced)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64,
                    help="max prompt length (lengths are uniform in "
                         "[prompt-len//4, prompt-len])")
    ap.add_argument("--gen", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at once")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    import jax
    import numpy as np
    from repro.configs import ParallelConfig, get_config
    from repro.launch.mesh import make_mesh_for
    from repro.models import transformer as tfm
    from repro.parallel import sharding
    from repro.serving import scheduler as sched
    from repro.training.checkpoint import CheckpointManager

    cfg = get_config(args.arch, residual=args.residual)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=256, n_heads=4, d_ff=512,
                          vocab_size=2048)
    if args.use_pallas:
        cfg = cfg.replace(use_pallas=True)
    pcfg = ParallelConfig(tp=args.tp, dp=args.dp)
    mesh = make_mesh_for(pcfg.world, args.tp) if pcfg.world > 1 else None

    params = tfm.init_params(cfg, jax.random.key(0))
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt)
        _, params, _, _ = mgr.restore(params)
        print(f"[serve] restored step {mgr.latest_step()}")
    params, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)

    if args.autotune:
        # local re-sweep: overwrite the in-process tuning table (and the
        # on-disk json) with fresh measurements for THIS host before the
        # engine traces its steps
        from repro.kernels import autotune
        table = autotune.sweep(block_sizes=(args.block_size,))
        autotune.save_table(table)
        autotune.get_table.cache_clear()
        n = len(table["entries"])
        print(f"[serve] autotune: swept {n} (phase, occupancy) entries "
              f"-> {autotune.TABLE_PATH}")

    s_max = args.prompt_len + args.gen + 1
    engine = None
    kind = args.engine
    if args.spec_decode != "off" and kind == "ragged":
        raise SystemExit("--spec-decode requires the paged engine")
    if kind == "ragged" and (args.kv_quant != "fp" or
                             args.oversubscribe != 1.0 or args.swap_blocks or
                             args.comm_overlap or args.comm_quant or
                             args.comm_fuse_norm):
        raise SystemExit("--kv-quant/--oversubscribe/--swap-blocks/"
                         "--comm-overlap/--comm-quant/--comm-fuse-norm "
                         "require the paged engine")
    if kind != "ragged":
        try:
            paged_kw = dict(
                batch_slots=args.slots, s_max=s_max, pcfg=pcfg, mesh=mesh,
                block_size=args.block_size,
                num_blocks=args.num_blocks or None,
                max_prefill_tokens=args.prefill_budget,
                kv_quant=args.kv_quant, oversubscribe=args.oversubscribe,
                swap_blocks=args.swap_blocks,
                comm_overlap=args.comm_overlap, comm_quant=args.comm_quant,
                comm_chunks=args.comm_chunks,
                comm_fuse_norm=args.comm_fuse_norm,
                tuned=not args.no_tune)
            if args.spec_decode != "off":
                from repro.serving.speculative import (
                    SpeculativePagedEngine, derive_draft_cfg)
                spec_kw = {}
                if args.spec_decode == "draft":
                    dcfg = derive_draft_cfg(cfg, args.draft_layers)
                    spec_kw = dict(
                        draft_cfg=dcfg,
                        draft_params=tfm.init_params(dcfg,
                                                     jax.random.key(1)))
                engine = SpeculativePagedEngine(
                    cfg, params, spec_mode=args.spec_decode,
                    spec_k=args.spec_k, **spec_kw, **paged_kw)
                kind = f"paged+spec:{args.spec_decode}"
            else:
                engine = sched.PagedServingEngine(cfg, params, **paged_kw)
                kind = "paged"
        except NotImplementedError as e:
            if args.engine == "paged" or args.spec_decode != "off" or \
                    args.kv_quant != "fp" or args.oversubscribe != 1.0 or \
                    args.swap_blocks or args.comm_overlap or \
                    args.comm_quant or args.comm_fuse_norm:
                # memory-tier/comm flags exist only on the paged path:
                # error instead of silently serving without them
                raise
            print(f"[serve] paged engine unavailable ({e}); using ragged")
    if engine is None:
        engine = sched.ContinuousServingEngine(
            cfg, params, batch_slots=args.slots, s_max=s_max, pcfg=pcfg,
            mesh=mesh)
        kind = "ragged"

    rng = np.random.default_rng(1)
    sampling = lambda rid: sched.SamplingParams(
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=rid)
    if args.rate > 0:
        trace = sched.poisson_trace(
            args.requests, args.rate, seed=1,
            prompt_lens=(max(1, args.prompt_len // 4), args.prompt_len),
            max_new=(max(1, args.gen // 2), args.gen),
            vocab=cfg.vocab_size, sampling=sampling)
        t0 = time.time()
        finished, tok_times = sched.serve_trace(engine, trace)
        wall = time.time() - t0
    else:
        trace = []
        for rid in range(args.requests):
            lp = int(rng.integers(max(1, args.prompt_len // 4),
                                  args.prompt_len + 1))
            trace.append(sched.Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, lp).tolist(),
                max_new_tokens=args.gen, sampling=sampling(rid)))
        for r in trace:
            engine.submit(r)
        t0 = time.time()
        finished = engine.run()
        wall = time.time() - t0

    n_tok = sum(len(f.tokens) for f in finished.values())
    # the paged-attention kernel only exists on the paged path; a ragged
    # fallback run must not be labelled as if the kernel served it
    pallas_tag = "+pallas" if args.use_pallas and kind.startswith("paged") \
        else ""
    comm_tag = ("+comm:int8+norm" if args.comm_fuse_norm else
                "+comm:int8" if args.comm_quant else
                "+comm:overlap" if args.comm_overlap else "")
    print(f"[serve] {len(finished)}/{len(trace)} requests, {n_tok} tokens "
          f"in {wall:.2f}s ({n_tok / max(wall, 1e-9):.1f} tok/s) "
          f"engine={kind}{pallas_tag}{comm_tag} "
          f"slots={args.slots} tp={args.tp} dp={args.dp}")
    if kind.startswith("paged"):
        st = engine.stats()
        print(f"[serve] paged: prefix_hit_rate={st['prefix_hit_rate']:.2f} "
              f"block_util mean={st['block_util_mean']:.2f} "
              f"peak={st['block_util_peak']:.2f} "
              f"allocs={st['total_block_allocs']} "
              f"deferred={st['deferred_admissions']} "
              f"kv_quant={args.kv_quant}")
        if "preemptions" in st:
            print(f"[serve] memory: preemptions={st['preemptions']} "
                  f"resumes={st['resumes']} "
                  f"swapped_out={st['swapped_out_blocks']} blocks "
                  f"(swap peak {st['swap_peak_blocks']}) "
                  f"oversubscribe={st['oversubscribe']:.2f}")
        if "accept_rate" in st:
            print(f"[serve] spec: accept_rate={st['accept_rate']:.2f} "
                  f"tokens_per_forward={st['tokens_per_forward']:.2f} "
                  f"verify_forwards={st['verify_forwards']} "
                  f"rolled_back_blocks={st['rolled_back_blocks']}")
    for f in list(finished.values())[:4]:
        print(f"[serve] rid={f.rid} prompt={len(f.prompt)} "
              f"-> {len(f.tokens)} toks ({f.finish_reason}): "
              f"{f.tokens[:12]}")


if __name__ == "__main__":
    main()
