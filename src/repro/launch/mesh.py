"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax init and only then calls it.

Mesh layout (TPU v5e pods):
  single-pod:  (16, 16)    axes (data, model)  = 256 chips
  multi-pod:   (2, 16, 16) axes (pod, data, model) = 512 chips

The 'model' axis carries tensor parallelism (the paper's subject) and maps
onto one ICI torus dimension; 'data' carries DP; 'pod' is either extra DP
(default) or pipeline stages (parallel/pp.py) across the inter-pod DCN.
"""

from __future__ import annotations

from repro.parallel import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh_for(world: int, tp: int, pods: int = 1):
    """Elastic helper: build a (pod, data, model) mesh for whatever device
    count is actually available (restart-after-failure path)."""
    assert world % (tp * pods) == 0, (world, tp, pods)
    dp = world // (tp * pods)
    if pods > 1:
        return compat.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return compat.make_mesh((dp, tp), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(mesh.shape)
