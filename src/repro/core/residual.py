"""Residual-stream topologies — the paper's core contribution.

A transformer stack is a sequence of *sub-blocks* (attention halves, MLP
halves, MoE FFNs, Mamba mixers, ...).  Each sub-block function returns a
TP-*partial* output that needs an AllReduce (psum over the model axis) to
complete.  This module owns BOTH the placement of that AllReduce and the
residual wiring around it — which is exactly the design space the paper
explores:

STANDARD (Eq. 1)   x_j = psum(h_j(x_{j-1})) + x_{j-1}
    The psum is on the critical path: h_{j+1} cannot start until it lands.

LADDER (Eq. 2)     x_j = psum(h_j(x_{j-2})) + x_{j-1}
    h_{j+1} consumes x_{j-1}, which is independent of psum(h_j(...)), so the
    XLA latency-hiding scheduler can run the AllReduce concurrently with the
    next sub-block's compute (async all-reduce-start/done — the JAX analogue
    of the paper's AsyncAllReduce handle).  Implemented as a rolling pair of
    "pending" outputs, mirroring Algorithm 1.

PARALLEL (PaLM)    fused at assembly time: consecutive (mixer, ffn) pairs
    compute from the same input and share one psum — this mode reaches this
    driver already fused, so it runs the STANDARD wiring over fused blocks.

DESYNC-nx (§5)     keep only every n-th AllReduce.  Correct resync semantics
    require reducing the *accumulated local delta* since the last sync (not
    just the current sub-block output); we carry that delta explicitly.

NO_COMM            drop every AllReduce — the paper's upper bound (incorrect
    math, benchmarking only).

All modes run unchanged at TP=1 (psum == identity), which the equivalence
tests exploit.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ResidualMode
from repro.parallel.collectives import AxisEnv, PendingResidual

# A sub-block: fn(group_params, x, state) -> (partial_out, new_state, aux)
SubBlockFn = Callable[[Any, jnp.ndarray, Any], Tuple[jnp.ndarray, Any, jnp.ndarray]]


@jax.tree_util.register_dataclass
@dataclass
class FusedNormInput:
    """A ladder sub-block input whose pending AllReduce is still int8.

    Under ``comm.fuse_norm`` the sub-block j input is conceptually
    ``base + dequant_sum(pending)`` but is handed to the sub-block
    UNSUMMED: the entry RMSNorm dequant-accumulates the images in VMEM
    (kernels/rmsnorm.rmsnorm_dequant via models/transformer.norm_in), so
    the pre-norm read streams int8 instead of round-tripping the summed
    f32 activation through HBM.  Sub-block functions consume their input
    only through ``norm_in``, which is what makes this a drop-in payload.
    """

    base: jnp.ndarray          # (B, S, D) residual stream (x_{j-1})
    pending: PendingResidual   # psum(h_{j-1}) still as per-source images


@dataclass
class Carry:
    """Scan carry for every topology (unused fields stay None per mode)."""

    residual: jnp.ndarray
    p1: Optional[jnp.ndarray] = None      # pending from sub-block j-1 (ladder)
    p2: Optional[jnp.ndarray] = None      # pending from sub-block j-2 (ladder)
    delta: Optional[jnp.ndarray] = None   # unsynced local delta (desync)
    aux: jnp.ndarray = None               # accumulated auxiliary loss

    def tree(self):
        return tuple(t for t in (self.residual, self.p1, self.p2, self.delta,
                                 self.aux) if t is not None)


def init_carry(mode: ResidualMode, x: jnp.ndarray,
               env: Optional[AxisEnv] = None) -> Carry:
    zero = jnp.zeros_like(x)
    aux = jnp.zeros((), jnp.float32)
    if mode == ResidualMode.LADDER:
        if env is not None and env.comm.fuse_norm and not env.sp:
            # fused-norm ladder: pendings live as deferred int8 image
            # stacks; an all-zero stack materializes to exactly zero
            # (scale 0), so the first two sub-blocks see x unchanged
            tp = env.tp
            zp = PendingResidual(
                images=jnp.zeros((tp, *x.shape), jnp.int8),
                scales=jnp.zeros((tp, *x.shape[:-1]), jnp.float32))
            return Carry(residual=x, p1=zp, p2=zp, aux=aux)
        return Carry(residual=x, p1=zero, p2=zero, aux=aux)
    if mode in (ResidualMode.DESYNC2, ResidualMode.DESYNC4):
        return Carry(residual=x, delta=zero, aux=aux)
    return Carry(residual=x, aux=aux)


def finalize_carry(mode: ResidualMode, carry: Carry, env: AxisEnv) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Flush pendings / deltas; returns (residual, aux_loss)."""
    r = carry.residual
    if mode == ResidualMode.LADDER:
        if isinstance(carry.p2, PendingResidual):
            r = carry.p1.materialize(carry.p2.materialize(r))
        else:
            r = r + carry.p2 + carry.p1
    elif mode in (ResidualMode.DESYNC2, ResidualMode.DESYNC4):
        # re-synchronize whatever local delta remains at the stack end
        r = r + env.psum_model(carry.delta)
    return r, carry.aux


def desync_period(mode: ResidualMode) -> int:
    return {ResidualMode.DESYNC2: 2, ResidualMode.DESYNC4: 4}.get(mode, 1)


def _name_collective(x):
    """Tag a reduced sub-block output so remat policies can SAVE it: the
    'coll_out' name lets `remat="save_collectives"` keep AllReduce results
    across the checkpoint boundary instead of re-communicating them during
    the backward recompute (§Perf hillclimb 1 — roughly halves the train
    collective term at the cost of one saved activation per sub-block)."""
    from jax.ad_checkpoint import checkpoint_name
    return jax.tree.map(lambda t: checkpoint_name(t, "coll_out"), x)


def subblock_step(mode: ResidualMode, fn: SubBlockFn, params, carry: Carry,
                  state, env: AxisEnv, sub_idx: int, desync_n: int = 1):
    """Advance one sub-block under the given topology.

    sub_idx: STATIC index phase of this sub-block — desync modes decide from
    it which AllReduces are retained, and that decision must be static so
    the dropped collectives are truly absent from the lowered HLO (the whole
    point of Desync Residual).  The assembler guarantees that scan bodies
    cover a whole number of desync periods, making the in-body phase static.
    Returns (carry, new_state).
    """
    if mode == ResidualMode.LADDER:
        # Algorithm 1: consume the psum issued two sub-blocks ago, then
        # compute from the (now one-step-stale) residual and issue this
        # sub-block's psum.  Between issue and consume, one full sub-block
        # of compute overlaps the collective.
        if isinstance(carry.p2, PendingResidual):
            # fuse_norm: hand the sub-block the UNSUMMED pending — its
            # entry RMSNorm dequant-accumulates the int8 images in VMEM —
            # and materialize the same sum (same source order, same f32
            # association) for the carried residual stream.
            out, new_state, aux = fn(
                params, FusedNormInput(base=carry.residual, pending=carry.p2),
                state)
            residual = carry.p2.materialize(carry.residual)
            pending = env.ring_block_output_images(out)
        else:
            residual = carry.residual + carry.p2
            out, new_state, aux = fn(params, residual, state)
            pending = env.reduce_block_output(out)
        pending = _name_collective(pending)
        return Carry(residual=residual, p1=pending, p2=carry.p1,
                     aux=carry.aux + aux), new_state

    if mode in (ResidualMode.DESYNC2, ResidualMode.DESYNC4):
        local = carry.residual + carry.delta
        out, new_state, aux = fn(params, local, state)
        delta = carry.delta + out
        if (sub_idx + 1) % desync_n == 0:   # static decision
            residual = carry.residual + env.psum_model(delta)
            delta = jnp.zeros_like(delta)
        else:
            residual = carry.residual
        return Carry(residual=residual, delta=delta,
                     aux=carry.aux + aux), new_state

    if mode == ResidualMode.NO_COMM:
        out, new_state, aux = fn(params, carry.residual, state)
        return Carry(residual=carry.residual + out,
                     aux=carry.aux + aux), new_state

    # STANDARD (and PARALLEL, which arrives pre-fused)
    out, new_state, aux = fn(params, carry.residual, state)
    reduced = env.reduce_block_output(out)
    reduced = _name_collective(reduced)
    return Carry(residual=carry.residual + reduced,
                 aux=carry.aux + aux), new_state


def run_section(mode: ResidualMode, fns: Sequence[SubBlockFn], params_stack,
                carry: Carry, env: AxisEnv, *, states=None,
                sub_idx0: int = 0, remat: str = "none",
                use_scan: bool = True, n_groups: Optional[int] = None,
                gather=None):
    """Run a homogeneous section of the stack: ``n_groups`` repetitions of the
    sub-block pattern ``fns``, with per-group parameters stacked on the
    leading axis of ``params_stack`` (and of ``states``, when present).

    gather: optional fn(group_params) -> group_params applied inside the
    (possibly remat'ed) group body — the FSDP weight all-gather hook.
    Returns (carry, new_states).
    """
    desync_n = desync_period(mode)
    k = len(fns)

    if n_groups is None:
        n_groups = jax.tree.leaves(params_stack)[0].shape[0]

    # Desync phases must be static inside a scan body: require the body to
    # cover a whole number of desync periods (the assembler arranges this by
    # choosing the scan super-group size); otherwise fall back to unrolling.
    if desync_n > 1 and use_scan and n_groups > 1 and \
            (k % desync_n != 0 or sub_idx0 % desync_n != 0):
        use_scan = False

    def group_body(carry: Carry, group_params, group_states, base_idx: int):
        if gather is not None:
            group_params = gather(group_params)
        new_states = [] if group_states is not None else None
        for j, fn in enumerate(fns):
            st = group_states[j] if group_states is not None else None
            carry, new_st = subblock_step(mode, fn, group_params, carry, st,
                                          env, base_idx + j, desync_n)
            if new_states is not None:
                new_states.append(new_st)
        return carry, (tuple(new_states) if new_states is not None else None)

    if remat != "none":
        if remat == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        elif remat == "save_collectives":
            policy = jax.checkpoint_policies.save_only_these_names("coll_out")
        else:
            policy = None

        def group_body_r(carry, gp, gs, base_idx):
            def wrapped(c_tuple, gp, gs):
                c = _carry_from_tuple(mode, c_tuple)
                c2, ns = group_body(c, gp, gs, base_idx)
                return c2.tree(), ns
            c_tuple, ns = jax.checkpoint(wrapped, policy=policy)(
                carry.tree(), gp, gs)
            return _carry_from_tuple(mode, c_tuple), ns
    else:
        group_body_r = group_body

    if not use_scan or n_groups == 1:
        new_states = [] if states is not None else None
        for g in range(n_groups):
            gp = jax.tree.map(lambda t: t[g], params_stack)
            gs = jax.tree.map(lambda t: t[g], states) if states is not None else None
            carry, ns = group_body_r(carry, gp, gs, sub_idx0 + g * k)
            if new_states is not None:
                new_states.append(ns)
        if new_states is not None:
            new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *new_states)
        return carry, new_states

    def scan_body(c_tuple, xs):
        gp, gs = xs
        c = _carry_from_tuple(mode, c_tuple)
        # in-scan phase: sub_idx0 is period-aligned and k covers whole
        # periods, so `sub_idx0 + j` has the correct static desync phase
        # for every group.
        c2, ns = group_body_r(c, gp, gs, sub_idx0)
        return c2.tree(), ns

    xs = (params_stack, states)
    c_tuple, new_states = jax.lax.scan(scan_body, carry.tree(), xs)
    return _carry_from_tuple(mode, c_tuple), new_states


def _carry_from_tuple(mode: ResidualMode, t) -> Carry:
    if mode == ResidualMode.LADDER:
        return Carry(residual=t[0], p1=t[1], p2=t[2], aux=t[3])
    if mode in (ResidualMode.DESYNC2, ResidualMode.DESYNC4):
        return Carry(residual=t[0], delta=t[1], aux=t[2])
    return Carry(residual=t[0], aux=t[1])


def fuse_parallel(mixer_fn: SubBlockFn, ffn_fn: SubBlockFn) -> SubBlockFn:
    """PaLM-style parallel block: mixer and FFN compute from the same input;
    their partial outputs share one AllReduce (half the communication)."""

    def fused(params, x, state):
        o1, st1, a1 = mixer_fn(params, x, state[0] if state is not None else None)
        o2, st2, a2 = ffn_fn(params, x, state[1] if state is not None else None)
        new_state = (st1, st2) if state is not None else None
        return o1 + o2, new_state, a1 + a2

    return fused
