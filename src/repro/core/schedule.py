"""Analytical communication/compute timeline model.

This is the quantitative form of the paper's argument: a transformer layer
is attention-compute, MLP-compute, and two AllReduces; the residual topology
decides which of these may run concurrently.  Per sub-block j:

  STANDARD   t = sum_j (t_comp_j + t_comm_j)           (comm blocks)
  LADDER     t = sum_j max(t_comp_j, t_comm_{j-1})     (comm hides under the
                                                        NEXT sub-block)
  PARALLEL   fused attn+mlp, one AllReduce per layer
  DESYNC-n   all compute + 1/n of the comms
  NO_COMM    compute only (the paper's upper bound)

Compute times follow a two-term roofline max(flops/peak, bytes/bw); comms a
latency + bytes/bandwidth line.  Hardware presets cover the paper's H100
setups (NVLink / PCIe-only / cross-node IB) and the TPU v5e target, so the
same model reproduces Table 1/2/6 + Figure 2/3 trends and projects them
onto the dry-run hardware.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ResidualMode


@dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float          # per device
    hbm_bw: float              # bytes/s
    link_bw: float             # bytes/s per device (ring, bidirectional sum)
    comm_latency: float        # seconds per collective
    mfu: float = 0.6           # achievable fraction of peak on matmuls


# the paper's benchmark hardware (H100 DGX, bf16)
H100_NVLINK = HW("H100+NVLink", 989e12, 3.35e12, 450e9, 8e-6, 0.65)
H100_NO_NVLINK = HW("H100 PCIe-only", 989e12, 3.35e12, 60e9, 25e-6, 0.65)
H100_CROSS_NODE = HW("H100 x-node IB", 989e12, 3.35e12, 50e9, 30e-6, 0.65)
TPU_V5E = HW("TPU v5e", 197e12, 819e9, 50e9, 5e-6, 0.6)

HWS = dict(nvlink=H100_NVLINK, no_nvlink=H100_NO_NVLINK,
           cross_node=H100_CROSS_NODE, v5e=TPU_V5E)


@dataclass
class LayerCost:
    t_attn: float
    t_mlp: float
    t_comm: float              # one AllReduce of the hidden activations


def _t_compute(flops, bytes_, hw: HW):
    return max(flops / (hw.peak_flops * hw.mfu), bytes_ / hw.hbm_bw)


def ar_wire_bytes(t: int, d: int, tp: int, *, quant: bool = False) -> float:
    """Bytes on the wire for one ring AllReduce of a (t, d) activation over
    tp shards: each element crosses a link 2(tp-1)/tp times (reduce-scatter
    + all-gather halves).  bf16 payload (2 B/elem) by default; the
    int8-compressed wire (parallel/overlap.py) pays 1 B/elem plus one f32
    scale per 256-element quant block."""
    if tp <= 1:
        return 0.0
    elems = t * d
    payload = elems * 1 + 4 * -(-elems // 256) if quant else elems * 2
    return 2 * (tp - 1) / tp * payload


def comm_time(wire_bytes: float, hw: HW, *, chunks: int = 1) -> float:
    """Latency + bandwidth line for one (possibly chunked) AllReduce.
    Each chunk is its own collective, so chunking multiplies the latency
    term — the price paid for chunk-level overlap.  With latency-dominated
    decode comm chunks=1 wins; chunking pays off on bandwidth-dominated
    prefill shapes (comm_bench sweeps this trade)."""
    if wire_bytes <= 0.0:
        return 0.0
    return chunks * hw.comm_latency + wire_bytes / hw.link_bw


def exposed_comm(mode: ResidualMode, lc: LayerCost) -> dict:
    """Per-layer exposed vs hidden comm time under `mode` — the
    quantitative form of "ladder can overlap where standard cannot",
    consistent with :func:`stack_time` (stack = n_layers * (t_attn + t_mlp
    + t_exposed) up to edge terms).

    STANDARD consumes each AllReduce's result immediately, so nothing can
    hide it: exposed == total.  LADDER consumes it one sub-block later, so
    each comm hides under the next sub-block's compute and only the excess
    is exposed.  DESYNC-n drops all but 1/n of the comms but the survivors
    are synchronous.  PARALLEL fuses to one (synchronous) comm per layer.
    """
    ta, tm, tc = lc.t_attn, lc.t_mlp, lc.t_comm
    if mode == ResidualMode.STANDARD:
        total = exposed = 2 * tc
    elif mode == ResidualMode.LADDER:
        total = 2 * tc
        exposed = max(0.0, tc - ta) + max(0.0, tc - tm)
    elif mode == ResidualMode.PARALLEL:
        total = exposed = tc
    elif mode in (ResidualMode.DESYNC2, ResidualMode.DESYNC4):
        n = {ResidualMode.DESYNC2: 2, ResidualMode.DESYNC4: 4}[mode]
        total = exposed = 2 * tc / n
    elif mode == ResidualMode.NO_COMM:
        total = exposed = 0.0
    else:
        raise ValueError(mode)
    hidden = total - exposed
    return dict(t_comm_total=total, t_exposed=exposed, t_hidden=hidden,
                hidden_frac=hidden / total if total > 0 else 0.0)


def layer_cost(cfg: ModelConfig, *, tp: int, batch: int, seq_new: int,
               kv_len: int, hw: HW, comm_chunks: int = 1,
               comm_quant: bool = False) -> LayerCost:
    """Per-layer sub-block costs for `seq_new` tokens against `kv_len` keys
    (seq_new == kv_len for prefill/train fwd, 1 for decode).  comm_chunks /
    comm_quant model the overlap/compressed wire formats of
    parallel/overlap.py (defaults reproduce the monolithic bf16 psum)."""
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    t = batch * seq_new
    # attention sub-block (per device)
    fl_proj = 2 * t * d * (hq + 2 * hkv) * hd / tp + 2 * t * d * hq * hd / tp
    fl_score = 2 * t * kv_len * hq * hd / tp * 2
    by_attn = (d * (hq + 3 * hkv) * hd * 2 / tp            # weights bf16
               + 2 * batch * kv_len * hkv * hd * 2 / max(tp, 1)  # KV cache
               + 4 * t * d * 2 / tp)                       # activations
    t_attn = _t_compute(fl_proj + fl_score, by_attn, hw)
    # mlp sub-block
    ff = cfg.moe.moe_d_ff * cfg.moe.top_k if cfg.moe else cfg.d_ff
    n_mats = 3 if cfg.gated_mlp else 2
    fl_mlp = 2 * t * d * ff * n_mats / tp
    by_mlp = n_mats * d * ff * 2 / tp + 4 * t * d * 2 / tp
    if cfg.moe:
        by_mlp = n_mats * d * cfg.moe.moe_d_ff * 2 * \
            max(cfg.moe.num_experts // tp, 1) + 4 * t * d * 2 / tp
    t_mlp = _t_compute(fl_mlp, by_mlp, hw)
    # AllReduce of the (t, d) activations over tp
    wire = ar_wire_bytes(t, d, tp, quant=comm_quant)
    t_comm = comm_time(wire, hw, chunks=comm_chunks)
    return LayerCost(t_attn, t_mlp, t_comm)


def stack_time(mode: ResidualMode, n_layers: int, lc: LayerCost,
               desync_n: int = 1) -> float:
    ta, tm, tc = lc.t_attn, lc.t_mlp, lc.t_comm
    if mode == ResidualMode.STANDARD:
        return n_layers * (ta + tc + tm + tc)
    if mode == ResidualMode.LADDER:
        # each comm overlaps the next sub-block's compute
        return n_layers * (max(ta, tc) + max(tm, tc)) + tc
    if mode == ResidualMode.PARALLEL:
        return n_layers * (ta + tm + tc)
    if mode in (ResidualMode.DESYNC2, ResidualMode.DESYNC4):
        n = {ResidualMode.DESYNC2: 2, ResidualMode.DESYNC4: 4}[mode]
        return n_layers * (ta + tm) + (2 * n_layers / n) * tc
    if mode == ResidualMode.NO_COMM:
        return n_layers * (ta + tm)
    raise ValueError(mode)


def generation_throughput(cfg: ModelConfig, mode: ResidualMode, *, tp: int,
                          batch: int, prompt: int, gen: int, hw: HW):
    """tokens/s over a (prefill + decode) generation task — the paper's
    benchmark protocol (1024 prompt + 512 generated)."""
    lc_p = layer_cost(cfg, tp=tp, batch=batch, seq_new=prompt,
                      kv_len=prompt, hw=hw)
    t_prefill = stack_time(mode, cfg.n_layers, lc_p)
    # decode at the mean KV length
    lc_d = layer_cost(cfg, tp=tp, batch=batch, seq_new=1,
                      kv_len=prompt + gen // 2, hw=hw)
    t_decode = stack_time(mode, cfg.n_layers, lc_d) * gen
    total = t_prefill + t_decode
    return dict(tok_per_s=batch * gen / total, t_prefill=t_prefill,
                t_decode_per_tok=t_decode / gen, total=total)


def speedup_table(cfg: ModelConfig, *, tp: int, batch: int, prompt: int,
                  gen: int, hw: HW):
    """All variants vs STANDARD (the paper's Table 1/2 protocol)."""
    base = generation_throughput(cfg, ResidualMode.STANDARD, tp=tp,
                                 batch=batch, prompt=prompt, gen=gen, hw=hw)
    rows = {}
    for mode in [ResidualMode.STANDARD, ResidualMode.PARALLEL,
                 ResidualMode.LADDER, ResidualMode.DESYNC2,
                 ResidualMode.DESYNC4, ResidualMode.NO_COMM]:
        r = generation_throughput(cfg, mode, tp=tp, batch=batch,
                                  prompt=prompt, gen=gen, hw=hw)
        rows[mode.value] = dict(
            tok_per_s=r["tok_per_s"],
            speedup=r["tok_per_s"] / base["tok_per_s"],
            prefill_improvement=1 - r["t_prefill"] / base["t_prefill"],
            decode_improvement=1 - r["t_decode_per_tok"] /
            base["t_decode_per_tok"])
    return rows
