from repro.core.residual import (Carry, finalize_carry, fuse_parallel,
                                 init_carry, run_section, subblock_step)
