"""Shared int8 quantization primitives.

Two symmetric-int8 layouts live here, serving two different memory systems:

* **Flat per-block** (``quantize_int8`` / ``dequantize_int8``, ``BLOCK`` =
  256 elements): the gradient-compression layout — an arbitrary array is
  flattened, padded, and quantized in 256-element blocks with one scale per
  block.  ``parallel/compression.py`` re-exports these for
  ``compressed_pmean`` (EF-int8 cross-pod gradient reduction).

* **Per-token, per-head** (``quantize_kv`` / ``dequantize_kv``): the KV-pool
  layout (serving/kv_cache.py int8 mode; DESIGN.md §KV memory tiers).  Each
  (token, head) vector of ``head_dim`` elements gets its own scale, so a
  token's quantized bytes are a pure function of that token's K/V alone.
  That granularity is what makes the paged pool's incremental writes exact:
  chunked prefill, decode, and speculative verify scatter tokens into a
  block at different times, and a shared per-block scale would have to be
  re-fitted (re-quantizing earlier tokens) on every write — breaking the
  chunked == one-shot bit-equality contract and the swap tier's
  "quantized bytes move, never re-quantized" idempotence rule.  Block
  structure still matters for the *placement* of the scales: the pool
  stores them block-major ((Hkv, num_blocks * block_size)), so the paged
  attention kernel walks scale tiles with the same logical -> physical
  block translation as the KV tiles.

Quantization error is bounded per element by ``scale / 2`` with
``scale = max|x| / 127`` over the quantization group (the round-to-nearest
half-step); tests/test_property.py pins both layouts to that bound.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BLOCK = 256

INT8_MAX = 127.0
_EPS = 1e-12


def _pad_to(x, m):
    n = x.shape[0]
    return jnp.pad(x, (0, -n % m)), n


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8.  Returns (q (N/B, B) int8, scale (N/B,))."""
    flat, n = _pad_to(g.astype(jnp.float32).reshape(-1), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / INT8_MAX
    q = jnp.round(blocks / jnp.maximum(scale, _EPS)[:, None])
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale, shape) -> jnp.ndarray:
    """Inverse of quantize_int8: (q (N/B, B) int8, scale (N/B,)) back to a
    float32 array of `shape` (padding introduced by blocking is dropped)."""
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(token, head) symmetric int8 over the trailing head_dim axis.

    x: (..., hd) float.  Returns (q (..., hd) int8, scale (...) float32)
    with ``q = round(x / scale)``, ``scale = max|x| / 127`` per leading
    index.  An all-zero vector quantizes to zeros with scale 0 (the
    dequantized image is exactly zero, not NaN).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / INT8_MAX
    q = jnp.round(xf / jnp.maximum(scale, _EPS)[..., None])
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of quantize_kv: q (..., hd) int8, scale (...) -> (..., hd)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
