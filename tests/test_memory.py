"""KV memory-tier tests (DESIGN.md §KV memory tiers).

Layers, bottom-up:

* BlockAllocator hardening — misuse (refcount underflow, freeing a live
  block, double free) raises instead of corrupting the free list; the free
  list is fully reusable after preemption-style mass frees.
* SwapPool — (seq, block-idx) keyed host tier: capacity accounting,
  overflow and double-insert guards.
* extract_blocks / insert_blocks — device <-> host round trips are
  byte-identical for fp pools and move int8 bytes + scales verbatim.
* PreemptivePagedScheduler — oversubscribed admission, victim policy
  (priority first, newest admission among equals), preempt/resume
  bookkeeping, resume deferral until blocks free up.
* int8 pool semantics — quantize-on-scatter / dequantize-on-gather, the
  kernel's in-VMEM dequant path vs the gather oracle, and a bounded
  int8-vs-fp logit error at the model level.
* Engine equivalences (the acceptance invariants) — preempt -> swap-out ->
  swap-in -> resume produces token streams bit-identical to the
  never-preempted run for ladder/standard/desync2, on the plain paged and
  the speculative engines (both drafters), fp and int8 pools; the TP=2
  group lives in tests/distributed_impl.py (``serve_memory``).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, ResidualMode
from repro.models import transformer as tfm
from repro.serving import engine as engine_mod
from repro.serving.kv_cache import (
    BlockAllocationError,
    BlockAllocator,
    PrefixCache,
    make_paged_kv_cache,
    paged_update,
    paged_view,
)
from repro.serving.memory import (
    PreemptivePagedScheduler,
    SwapPool,
    extract_blocks,
    insert_blocks,
)
from repro.serving.scheduler import (
    PagedServingEngine,
    Request,
    SamplingParams,
)


# ---------------------------------------------------------------------------
# allocator hardening (no jax)
# ---------------------------------------------------------------------------


def test_allocator_double_decref_raises():
    a = BlockAllocator(num_blocks=2, block_size=4)
    blk = a.alloc()
    assert a.decref(blk) == 0
    with pytest.raises(ValueError, match="underflow"):
        a.decref(blk)
    assert a.refcount(blk) == 0  # state untouched by the failed decref


def test_allocator_free_guards():
    a = BlockAllocator(num_blocks=2, block_size=4)
    blk = a.alloc()
    with pytest.raises(ValueError, match="live block"):
        a.free(blk)  # refcount still 1
    a.decref(blk)
    a.free(blk)
    with pytest.raises(ValueError, match="double free"):
        a.free(blk)
    assert a.num_free() == 2  # the double free did not duplicate the entry


def test_allocator_incref_of_free_block_raises():
    a = BlockAllocator(num_blocks=1, block_size=4)
    with pytest.raises(ValueError, match="free-listed"):
        a.incref(0)


def test_allocator_oom_is_distinct_allocation_error():
    a = BlockAllocator(num_blocks=1, block_size=4)
    a.alloc()
    with pytest.raises(BlockAllocationError):
        a.alloc()
    # BlockAllocationError is a RuntimeError subclass (old callers catch it)
    assert issubclass(BlockAllocationError, RuntimeError)


def test_allocator_free_list_reusable_after_mass_frees():
    """Preemption frees a whole row's blocks at once; the pool must hand
    every one of them out again with refcounts intact."""
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = [a.alloc() for _ in range(8)]
    for blk in blocks[2:7]:  # preemption-style mass release
        a.decref(blk)
        a.free(blk)
    assert a.num_free() == 5
    again = [a.alloc() for _ in range(5)]
    assert sorted(again) == sorted(blocks[2:7])
    assert len(set(again)) == 5  # no block handed out twice
    assert all(a.refcount(b) == 1 for b in again)
    with pytest.raises(BlockAllocationError):
        a.alloc()


# ---------------------------------------------------------------------------
# swap pool (no jax)
# ---------------------------------------------------------------------------


def test_swap_pool_keys_and_capacity():
    sp = SwapPool(capacity_blocks=2)
    sp.put(7, 0, ["a"])
    sp.put(7, 1, ["b"])
    with pytest.raises(ValueError, match="occupied"):
        sp.put(7, 0, ["dup"])
    with pytest.raises(RuntimeError, match="capacity"):
        sp.put(8, 0, ["c"])
    assert sp.take(7, 0) == ["a"]
    sp.put(8, 0, ["c"])  # freed capacity is reusable
    assert sp.num_held() == 2 and sp.peak_blocks == 2
    assert sp.take_seq(8, 1) == [["c"]]


def test_swap_pool_seq_put_checks_capacity_upfront():
    sp = SwapPool(capacity_blocks=2)
    with pytest.raises(RuntimeError, match="cannot"):
        sp.put_seq(1, [["a"], ["b"], ["c"]])
    assert sp.num_held() == 0  # nothing partially inserted
    sp2 = SwapPool()  # unbounded
    sp2.put_seq(1, [["a"]] * 10)
    assert sp2.num_held() == 10


# ---------------------------------------------------------------------------
# block movement round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quant", ["fp", "int8"])
def test_extract_insert_block_round_trip(quant):
    """Swap-out -> swap-in restores pool bytes exactly, into the SAME or
    DIFFERENT physical blocks (resume re-allocates).  For int8 the
    quantized bytes and scales move verbatim — never re-quantized."""
    bs, hkv, hd, nb = 4, 2, 8, 6
    cache = make_paged_kv_cache(nb, bs, hkv, hd, jnp.float32, quant=quant)
    key = jax.random.key(0)
    kn = jax.random.normal(key, (1, 12, hkv, hd))
    vn = jax.random.normal(jax.random.fold_in(key, 1), (1, 12, hkv, hd))
    bt = jnp.asarray([[4, 1, 3]], jnp.int32)
    cache = paged_update(cache, kn, vn, jnp.arange(12)[None], bt)
    caches = [(cache,)]

    payloads = extract_blocks(caches, [4, 1, 3], bs)
    assert len(payloads) == 3
    # restore into different physical blocks: the logical view must match
    restored = insert_blocks(caches, [0, 2, 5], payloads, bs)
    bt2 = jnp.asarray([[0, 2, 5]], jnp.int32)
    want = paged_view(caches[0][0], bt)
    got = paged_view(restored[0][0], bt2)
    np.testing.assert_array_equal(np.asarray(want.k), np.asarray(got.k))
    np.testing.assert_array_equal(np.asarray(want.v), np.asarray(got.v))
    if quant == "int8":
        old, new = caches[0][0], restored[0][0]
        for blk_old, blk_new in zip([4, 1, 3], [0, 2, 5]):
            sl_o = slice(blk_old * bs, (blk_old + 1) * bs)
            sl_n = slice(blk_new * bs, (blk_new + 1) * bs)
            np.testing.assert_array_equal(  # raw int8 bytes, not dequant
                np.asarray(old.k[:, sl_o]), np.asarray(new.k[:, sl_n])
            )
            np.testing.assert_array_equal(
                np.asarray(old.k_scale[:, sl_o]),
                np.asarray(new.k_scale[:, sl_n]),
            )


# ---------------------------------------------------------------------------
# preemptive scheduler host logic (no jax)
# ---------------------------------------------------------------------------


def _psched(n_slots=2, s_max=32, num_blocks=8, bs=4, oversubscribe=2.0, **kw):
    return PreemptivePagedScheduler(
        n_slots,
        s_max,
        BlockAllocator(num_blocks, bs),
        prefix_cache=PrefixCache(),
        oversubscribe=oversubscribe,
        **kw,
    )


def _drive_prefill(s, tok=7):
    for slot, chunk, start in s.prefill_work():
        seq = s.slots[slot]
        s.chunk_filled(slot, len(chunk))
        if start + len(chunk) == len(seq.request.prompt):
            s.start_decode(slot, tok)


def test_oversubscribed_admission_admits_beyond_reservations():
    """Two requests each worst-case 4 blocks; pool of 6.  The strict
    scheduler defers the second, the oversubscribing one admits both
    (prompt blocks are physically covered; only reservations float)."""
    mk = lambda rid: Request(rid=rid, prompt=list(range(6)), max_new_tokens=9)
    strict = _psched(num_blocks=6, oversubscribe=1.0)
    strict.submit(mk(0))
    strict.submit(mk(1))
    assert [r.rid for _, r in strict.admissions()] == [0]
    assert strict.deferred_admissions == 1

    over = _psched(num_blocks=6, oversubscribe=2.0)
    over.submit(mk(0))
    over.submit(mk(1))
    assert [r.rid for _, r in over.admissions()] == [0, 1]


def test_admission_still_requires_physical_prompt_blocks():
    """Oversubscription never floats the blocks allocated RIGHT NOW: a
    9-token prompt (3 blocks) must defer when only 2 physical blocks are
    free, no matter the factor."""
    s = _psched(n_slots=2, num_blocks=5, oversubscribe=10.0)
    s.submit(Request(rid=0, prompt=list(range(10)), max_new_tokens=2))
    s.admissions()
    assert s.allocator.num_free() == 2
    s.submit(Request(rid=1, prompt=list(range(9)), max_new_tokens=2))
    assert s.admissions() == []
    assert s.deferred_admissions == 1


def test_victim_policy_priority_then_newest():
    s = _psched(n_slots=3, num_blocks=24, bs=4, oversubscribe=1.0)
    for rid, prio in [(0, 1), (1, 0), (2, 0)]:
        s.submit(Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=4, priority=prio))
    s.admissions()
    _drive_prefill(s)
    # priorities: rid0=1, rid1=0, rid2=0 -> lowest priority first, newest
    # admission among equals: victim is rid2's slot
    victim = s.pick_victim()
    assert s.slots[victim].request.rid == 2
    s.preempt(victim)
    assert s.slots[s.pick_victim()].request.rid == 1
    s.preempt(s.pick_victim())
    assert s.slots[s.pick_victim()].request.rid == 0


def test_preempt_resume_bookkeeping():
    s = _psched(n_slots=2, num_blocks=6, bs=4, oversubscribe=2.0)
    s.submit(Request(rid=0, prompt=list(range(6)), max_new_tokens=9))
    s.submit(Request(rid=1, prompt=list(range(6)), max_new_tokens=9))
    s.admissions()
    _drive_prefill(s)
    in_use = s.allocator.num_in_use()
    reserved = s.total_reserved
    seq1 = s.slots[1]
    held = len(seq1.blocks)

    victim = s.pick_victim()
    assert victim == 1  # same priority, newest admission
    s.preempt(victim)
    assert s.slots[1] is None and s.has_work()
    assert s.allocator.num_in_use() == in_use - held
    assert s.total_reserved == reserved - seq1.reserved
    assert seq1.swapped_blocks == held and seq1.blocks == []

    # resume restores the reservation and allocates the same block count
    slot, seq = s.resume_ready()
    assert seq is seq1 and len(seq.blocks) == held
    assert s.total_reserved == reserved
    assert s.resume_ready() is None  # queue drained


def test_resume_defers_until_blocks_free():
    s = _psched(n_slots=2, num_blocks=6, bs=4, oversubscribe=2.0)
    s.submit(Request(rid=0, prompt=list(range(6)), max_new_tokens=17))
    s.submit(Request(rid=1, prompt=list(range(6)), max_new_tokens=9))
    s.admissions()
    _drive_prefill(s)
    s.preempt(1)
    # row 0 grows into its (fully backed) reservation until the pool is too
    # tight for row 1's two swapped-out blocks
    while s.allocator.num_free() >= 2:
        s.slots[0].pos += 4
        s.ensure_blocks_through(0, s.slots[0].pos)
    assert s.resume_ready() is None
    # retire row 0 -> its blocks free -> row 1 resumes
    s.slots[0].tokens = [9] * 17
    s._maybe_retire(0)
    slot, seq = s.resume_ready()
    assert seq.request.rid == 1


# ---------------------------------------------------------------------------
# int8 pool semantics + kernel dequant path
# ---------------------------------------------------------------------------


def test_int8_update_quantizes_per_token_and_is_write_order_invariant():
    """Writing a block's tokens across two scatters yields byte-identical
    pool state to one scatter — per-(token, head) scales make a token's
    bytes a pure function of that token's K/V (the chunked == one-shot
    contract)."""
    bs, hkv, hd, nb = 4, 1, 8, 4
    key = jax.random.key(1)
    kn = jax.random.normal(key, (1, 6, hkv, hd)) * 3
    vn = jax.random.normal(jax.random.fold_in(key, 1), (1, 6, hkv, hd))
    bt = jnp.asarray([[2, 0]], jnp.int32)

    one = make_paged_kv_cache(nb, bs, hkv, hd, jnp.float32, quant="int8")
    one = paged_update(one, kn, vn, jnp.arange(6)[None], bt)

    two = make_paged_kv_cache(nb, bs, hkv, hd, jnp.float32, quant="int8")
    two = paged_update(two, kn[:, :3], vn[:, :3], jnp.arange(3)[None], bt)
    two = paged_update(two, kn[:, 3:], vn[:, 3:], jnp.arange(3, 6)[None], bt)

    for name in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            np.asarray(getattr(one, name)), np.asarray(getattr(two, name))
        )


@pytest.mark.parametrize(
    "bs,g,q_len,softcap",
    [
        (8, 2, 1, 0.0),  # GQA decode
        (4, 4, 1, 30.0),  # GQA decode + softcap
        (8, 2, 5, 0.0),  # K+1 speculative verify
    ],
)
def test_int8_kernel_matches_int8_gather_oracle(bs, g, q_len, softcap):
    """The kernel's in-VMEM dequant (int8 tile * scale tile) must agree
    with the paged_view gather oracle's dequantized read."""
    from repro.kernels.paged_attention import paged_attention
    from repro.models.attention import _cached_attention
    from repro.parallel.collectives import NULL_ENV

    b, hkv, hd, num_blocks, m = 3, 2, 32, 16, 4
    key = jax.random.key(2)
    hq = hkv * g
    cache = make_paged_kv_cache(num_blocks, bs, hkv, hd, jnp.float32, quant="int8")
    rng = np.random.default_rng(0)
    bt = np.zeros((b, m), np.int32)
    for row in range(b):
        bt[row] = rng.choice(num_blocks, size=m, replace=False)
    bt = jnp.asarray(bt)
    kn = jax.random.normal(key, (b, m * bs, hkv, hd)) * 2
    vn = jax.random.normal(jax.random.fold_in(key, 1), (b, m * bs, hkv, hd))
    cache = paged_update(
        cache, kn, vn, jnp.broadcast_to(jnp.arange(m * bs), (b, m * bs)), bt
    )
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, q_len, hq, hd))
    base = jnp.asarray([2, bs + 3, m * bs - q_len])[:b]
    ar = jnp.arange(q_len)[None, :]
    klen = jnp.asarray([q_len, max(1, q_len - 2), 1])[:b]
    qpos = jnp.where(ar < klen[:, None], base[:, None] + ar, -1)
    qpos = qpos.astype(jnp.int32)

    scale = hd**-0.5
    got = paged_attention(
        q,
        cache.k,
        cache.v,
        bt,
        qpos,
        scale=scale,
        block_size=bs,
        softcap=softcap,
        k_scale=cache.k_scale,
        v_scale=cache.v_scale,
        interpret=True,
    )
    want = _cached_attention(
        q * scale, paged_view(cache, bt), qpos, NULL_ENV, softcap=softcap
    )
    valid = (qpos >= 0)[:, :, None, None]
    np.testing.assert_allclose(
        np.where(valid, got, 0),
        np.where(valid, want, 0),
        atol=2e-5,
        rtol=2e-5,
    )


def _tiny_cfg(mode):
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=256
    )
    return cfg.replace(residual_mode=ResidualMode(mode))


def test_int8_vs_fp_logit_error_bounded():
    """Decode logits from an int8 pool must stay within a small bound of
    the fp pool's — the quality contract that makes int8 deployable.
    (tests/test_property.py carries the hypothesis round-trip bound; this
    pins the error end-to-end through attention + MLP + lm head.)"""
    from repro.configs.base import ParallelConfig
    from repro.parallel.tp import make_axis_env

    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    env = make_axis_env(ParallelConfig())
    bt = jnp.arange(4, dtype=jnp.int32)[None]
    for seed in range(3):
        rng = np.random.default_rng(seed)
        toks = jnp.asarray(rng.integers(0, 256, (1, 12)), jnp.int32)
        logits = {}
        for quant in ("fp", "int8"):
            caches, _ = engine_mod.build_caches(
                cfg,
                1,
                64,
                ParallelConfig(),
                for_decode=False,
                paged=True,
                num_blocks=8,
                block_size=4,
                kv_quant=quant,
            )
            hidden, _, _ = tfm.forward(
                cfg,
                params,
                toks,
                env,
                positions=jnp.arange(12)[None],
                caches=caches,
                block_tables=bt,
            )
            logits[quant] = np.asarray(tfm.logits_shard(cfg, params, hidden[:, -1:]))
        err = np.abs(logits["fp"] - logits["int8"]).max()
        ref = np.abs(logits["fp"]).max()
        assert err <= 0.05 * (1.0 + ref), (err, ref)  # measured ~0.004


# ---------------------------------------------------------------------------
# engine equivalences (the acceptance invariants)
# ---------------------------------------------------------------------------


def _trace(vocab, rng):
    cases = [
        ([5, 6, 7, 5, 6, 7, 5, 6], 8, SamplingParams()),
        (
            rng.integers(0, vocab, 12).tolist(),
            6,
            SamplingParams(temperature=0.9, top_k=12, seed=3),
        ),
        ([5, 6, 7, 5, 6, 7], 7, SamplingParams()),
        (
            rng.integers(0, vocab, 9).tolist(),
            5,
            SamplingParams(temperature=0.8, top_p=0.9, seed=11),
        ),
    ]
    return [
        Request(rid=i, prompt=p, max_new_tokens=g, sampling=sp)
        for i, (p, g, sp) in enumerate(cases)
    ]


def _clone(r):
    return Request(
        rid=r.rid,
        prompt=list(r.prompt),
        max_new_tokens=r.max_new_tokens,
        sampling=r.sampling,
        priority=r.priority,
    )


def _run(engine, reqs):
    for r in reqs:
        engine.submit(_clone(r))
    return {rid: f.tokens for rid, f in engine.run().items()}


@pytest.mark.parametrize("mode", ["ladder", "standard", "desync2"])
def test_preempted_engine_matches_unpreempted(mode):
    """preempt -> swap-out -> swap-in -> resume is bit-invisible: a tiny
    oversubscribed pool (preemption provably engaged) emits token streams
    identical to a roomy never-preempting pool."""
    cfg = _tiny_cfg(mode)
    params = tfm.init_params(cfg, jax.random.key(0))
    reqs = _trace(cfg.vocab_size, np.random.default_rng(0))

    roomy = PagedServingEngine(cfg, params, batch_slots=3, s_max=48, block_size=4)
    want = _run(roomy, reqs)

    tight = PagedServingEngine(
        cfg,
        params,
        batch_slots=3,
        s_max=48,
        block_size=4,
        num_blocks=8,
        oversubscribe=2.5,
    )
    got = _run(tight, reqs)
    st = tight.stats()
    assert st["preemptions"] > 0 and st["resumes"] > 0
    assert st["swapped_out_blocks"] == st["swapped_in_blocks"]
    assert got == want


@pytest.mark.parametrize("spec_mode", ["ngram", "draft"])
def test_preempted_speculative_engine_matches_plain(spec_mode):
    """Speculative rollback composes with preemption: the oversubscribed
    speculative engine still emits bit-identical streams to plain decode,
    for both drafters."""
    from repro.serving.speculative import SpeculativePagedEngine

    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    reqs = _trace(cfg.vocab_size, np.random.default_rng(1))

    plain = PagedServingEngine(cfg, params, batch_slots=3, s_max=48, block_size=4)
    want = _run(plain, reqs)

    kw = {}
    if spec_mode == "draft":
        dcfg = cfg.reduced(n_layers=1)
        kw = dict(
            draft_cfg=dcfg,
            draft_params=tfm.init_params(dcfg, jax.random.key(7)),
        )
    spec = SpeculativePagedEngine(
        cfg,
        params,
        batch_slots=3,
        s_max=48,
        block_size=4,
        num_blocks=8,
        oversubscribe=2.5,
        spec_mode=spec_mode,
        spec_k=3,
        **kw,
    )
    got = _run(spec, reqs)
    st = spec.stats()
    assert st["preemptions"] > 0 and st["verify_forwards"] > 0
    assert got == want


@pytest.mark.parametrize("use_pallas", [False, True])
def test_int8_engine_preempt_and_kernel_match_oracle(use_pallas):
    """int8 pools: the preempted-and-resumed run matches the
    never-preempted int8 run bit-exactly (quantized bytes moved, never
    re-quantized), through both the gather oracle and the kernel's
    dequant-in-VMEM path — and kernel == oracle."""
    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    reqs = _trace(cfg.vocab_size, np.random.default_rng(2))

    roomy = PagedServingEngine(
        cfg,
        params,
        batch_slots=3,
        s_max=48,
        block_size=4,
        kv_quant="int8",
        use_pallas=use_pallas,
    )
    want = _run(roomy, reqs)

    tight = PagedServingEngine(
        cfg,
        params,
        batch_slots=3,
        s_max=48,
        block_size=4,
        num_blocks=8,
        oversubscribe=2.5,
        kv_quant="int8",
        use_pallas=use_pallas,
    )
    got = _run(tight, reqs)
    assert tight.stats()["preemptions"] > 0
    assert got == want


def test_int8_kernel_engine_matches_int8_gather_engine():
    """use_pallas=True over an int8 pool emits the same tokens as the int8
    gather oracle path — the engine-level pin of the kernel dequant."""
    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    reqs = _trace(cfg.vocab_size, np.random.default_rng(3))
    outs = []
    for pallas in (False, True):
        eng = PagedServingEngine(
            cfg,
            params,
            batch_slots=2,
            s_max=48,
            block_size=8,
            max_prefill_tokens=16,
            kv_quant="int8",
            use_pallas=pallas,
        )
        outs.append(_run(eng, reqs))
    assert outs[0] == outs[1]


def test_swap_pool_capacity_guard_surfaces_cleanly():
    """A bounded swap tier that cannot hold a victim raises a clear error
    instead of silently dropping blocks."""
    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    reqs = _trace(cfg.vocab_size, np.random.default_rng(0))
    eng = PagedServingEngine(
        cfg,
        params,
        batch_slots=3,
        s_max=48,
        block_size=4,
        num_blocks=8,
        oversubscribe=2.5,
        swap_blocks=1,  # too small for any whole row
    )
    with pytest.raises(RuntimeError, match="SwapPool"):
        _run(eng, reqs)
