"""End-to-end behaviour tests: every assigned architecture instantiates a
reduced config of its family, runs one forward and one train step on CPU,
and produces finite outputs/gradients of the right shapes."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, REGISTRY, ResidualMode, TrainConfig
from repro.models.model import build_model, count_params
from repro.parallel.collectives import NULL_ENV
from repro.parallel import tp as tpmod


def _batch_for(cfg, b=2, s=16, key=1):
    tokens = jax.random.randint(jax.random.key(key), (b, s), 0,
                                cfg.vocab_size)
    batch = dict(tokens=tokens, targets=jnp.roll(tokens, -1, axis=1))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (b, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (b, s * cfg.encoder_seq_ratio,
                                cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward(arch):
    """Reduced config of the same family: forward, shape + finiteness."""
    cfg = REGISTRY[arch].reduced()
    init, apply = build_model(cfg)
    params = init(jax.random.key(0))
    batch = _batch_for(cfg)
    kw = {}
    if cfg.family == "vlm":
        kw["frontend_embeds"] = batch["patches"]
    if cfg.encoder_layers:
        kw["frontend_embeds"] = batch["frames"]
    hidden, _, aux = apply(params, batch["tokens"], NULL_ENV, **kw)
    exp_s = batch["tokens"].shape[1] + (cfg.num_patches
                                        if cfg.family == "vlm" else 0)
    assert hidden.shape == (2, exp_s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    """One loss+grad step: finite loss, finite grads, positive loss."""
    cfg = REGISTRY[arch].reduced()
    init, _ = build_model(cfg)
    params = init(jax.random.key(0))
    batch = _batch_for(cfg)

    def loss_fn(p):
        return tpmod.lm_loss(cfg, p, batch, NULL_ENV, TrainConfig(),
                             train=True)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts_order_of_magnitude(arch):
    """Full-config parameter counts land near the advertised sizes."""
    import re
    cfg = REGISTRY[arch]
    n = count_params(cfg)
    m = re.search(r"(\d+(?:\.\d+)?)b", arch)
    if not m:  # whisper-small ~100M-ish backbone
        assert 5e7 < n < 5e8
        return
    target = float(m.group(1)) * 1e9
    assert 0.5 * target < n < 2.1 * target, (arch, n, target)


def test_residual_modes_all_finite():
    base = REGISTRY["stablelm-3b"].reduced(n_layers=4)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                base.vocab_size)
    outs = {}
    for mode in ResidualMode:
        cfg = base.replace(residual_mode=mode)
        init, apply = build_model(cfg)
        params = init(jax.random.key(0))
        h, _, _ = apply(params, tokens, NULL_ENV)
        assert bool(jnp.all(jnp.isfinite(h))), mode
        outs[mode] = h
    # at TP=1: desync/no_comm degenerate to standard; ladder/parallel differ
    std = outs[ResidualMode.STANDARD]
    assert jnp.allclose(outs[ResidualMode.DESYNC2], std, atol=1e-5)
    assert jnp.allclose(outs[ResidualMode.DESYNC4], std, atol=1e-5)
    assert jnp.allclose(outs[ResidualMode.NO_COMM], std, atol=1e-5)
    assert float(jnp.max(jnp.abs(outs[ResidualMode.LADDER] - std))) > 1e-2
    assert float(jnp.max(jnp.abs(outs[ResidualMode.PARALLEL] - std))) > 1e-2


def test_hybrid_ladder_start_layer():
    """Hybrid adaptation (§4.2): lower layers standard, upper layers ladder;
    ladder_start_layer == n_layers must equal pure standard."""
    base = REGISTRY["stablelm-3b"].reduced(n_layers=4)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                base.vocab_size)

    def out(cfg):
        init, apply = build_model(cfg)
        return apply(init(jax.random.key(0)), tokens, NULL_ENV)[0]

    std = out(base.replace(residual_mode=ResidualMode.STANDARD))
    full = out(base.replace(residual_mode=ResidualMode.LADDER))
    off = out(base.replace(residual_mode=ResidualMode.LADDER,
                           ladder_start_layer=5))
    hybrid = out(base.replace(residual_mode=ResidualMode.LADDER,
                              ladder_start_layer=2))
    assert jnp.allclose(off, std, atol=1e-5)
    assert float(jnp.max(jnp.abs(hybrid - std))) > 1e-3
    assert float(jnp.max(jnp.abs(hybrid - full))) > 1e-3


def test_ladder_matches_paper_equation():
    """Ladder Eq. (2) hand-rolled vs the topology driver, tiny stack."""
    import numpy as np
    from repro.core import residual as topo
    from repro.configs.base import ResidualMode as RM

    d = 8
    n_sub = 6
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
          for _ in range(n_sub)]
    x0 = jnp.asarray(rng.normal(size=(2, 3, d)), jnp.float32)

    def h(i, x):
        return jnp.tanh(x @ ws[i])

    # reference: x_i = h_i(x_{i-2}) + x_{i-1}
    xs = [x0, x0]  # x_{-1} = x_0 convention (h_1 sees x_0)
    for i in range(n_sub):
        xs.append(h(i, xs[-2]) + xs[-1])
    ref = xs[-1]

    fns = [lambda p, x, st, i=i: (h(i, x), st, jnp.zeros((), jnp.float32))
           for i in range(n_sub)]
    carry = topo.init_carry(RM.LADDER, x0)
    for i in range(n_sub):
        carry, _ = topo.subblock_step(RM.LADDER, fns[i], None, carry, None,
                                      NULL_ENV, i)
    got, _ = topo.finalize_carry(RM.LADDER, carry, NULL_ENV)
    assert jnp.allclose(got, ref, atol=1e-5)
