"""Kernel autotuner + ragged prefill + fused-norm tests (DESIGN.md
§Kernel autotuner).

Layers, bottom-up:

* table plumbing — round-trip through ``save_table``/``load_table``,
  schema rejection (including a persisted entry that beats the roofline
  bound — measurement noise must never be committed as a tuning), and the
  deterministic fallback: a missing key/arch/table resolves to the same
  config tuned-off uses, so a deleted table can change speed but never
  tokens.
* sweep mechanics — roofline rejection drops too-fast-to-be-true
  measurements before the argmin; a sweep whose every candidate is
  rejected still emits the deterministic default.
* ragged prefill / append kernel vs the gather oracle — the q-tiled mode
  chunked prefill and speculative verify dispatch through, swept over
  chunk-vs-block-boundary misalignment, GQA, int8 pools, and
  poisoned-pool isolation; q_tile is output-invariant (it only re-tiles
  the same per-query online softmax).
* fused dequant+RMSNorm — Pallas kernel vs jnp oracle is bit-identical,
  and a ladder paged engine with ``comm_fuse_norm`` streams the same
  tokens either way (the TP=2 group lives in tests/distributed_impl.py:
  ``serve_tuned``).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, ResidualMode
from repro.kernels import autotune, ops
from repro.kernels.paged_attention import paged_attention, prefill_kernel_blocks
from repro.models import transformer as tfm
from repro.models.attention import _cached_attention
from repro.models.layers import rmsnorm_dequant
from repro.parallel.collectives import NULL_ENV
from repro.quant import dequantize_kv, quantize_kv
from repro.serving.kv_cache import PagedKVCache, paged_view
from repro.serving.scheduler import PagedServingEngine, Request, SamplingParams


# ---------------------------------------------------------------------------
# table round-trip, schema, deterministic fallback
# ---------------------------------------------------------------------------


def _entry(block_size=8, num_splits=2, q_tile=0, tuned_us=10.0, default_us=12.0,
           bound_us=1.0):
    return dict(block_size=block_size, num_splits=num_splits, q_tile=q_tile,
                tuned_us=tuned_us, default_us=default_us, bound_us=bound_us)


def _table(entries):
    return dict(version=autotune.TABLE_VERSION, arch="test", entries=entries)


def test_table_round_trip(tmp_path):
    path = tmp_path / "tuning.json"
    table = _table({autotune.entry_key("test", "decode", 0.25): _entry()})
    autotune.save_table(table, path)
    assert autotune.load_table(path) == table
    cfg = autotune.get_config("decode", 0.25, table=table, arch="test")
    assert (cfg.num_splits, cfg.q_tile) == (2, 0)


@pytest.mark.parametrize(
    "mutate",
    [
        lambda t: t.update(version=99),
        lambda t: t.pop("entries"),
        lambda t: t["entries"].update({"no-phase-key": _entry()}),
        lambda t: t["entries"].update(
            {"test/warmup/occ1.0": _entry()}),  # unknown phase
        lambda t: t["entries"]["test/decode/occ0.25"].update(
            num_splits="two"),
        lambda t: t["entries"]["test/decode/occ0.25"].update(
            tuned_us=15.0),  # slower than default: sweep bug
        lambda t: t["entries"]["test/decode/occ0.25"].update(
            tuned_us=0.5),  # beats roofline bound: committed noise
    ],
)
def test_schema_rejection(tmp_path, mutate):
    table = _table({autotune.entry_key("test", "decode", 0.25): _entry()})
    mutate(table)
    with pytest.raises(ValueError):
        autotune.validate_table(table)
    # strict load refuses the same table; lenient load treats it as absent
    path = tmp_path / "bad.json"
    path.write_text(__import__("json").dumps(table))
    with pytest.raises(ValueError):
        autotune.load_table(path)
    assert autotune.load_table(path, strict=False) == {}


def test_deterministic_fallback():
    default = autotune.default_config("decode")
    # empty table, missing key, and foreign arch all resolve identically
    assert autotune.get_config("decode", 1.0, table={}) == default
    table = _table({autotune.entry_key("test", "decode", 0.25): _entry()})
    assert autotune.get_config("decode", 1.0, table=table,
                               arch="test") == default
    assert autotune.get_config("decode", 0.25, table=table,
                               arch="other-arch") == default
    with pytest.raises(ValueError):
        autotune.get_config("warmup", 1.0, table=table)
    with pytest.raises(ValueError):
        autotune.default_config("warmup")


def test_occupancy_bucket_snaps_up():
    assert autotune.occupancy_bucket(0.01) == "0.125"
    assert autotune.occupancy_bucket(0.125) == "0.125"
    assert autotune.occupancy_bucket(0.3) == "0.5"
    assert autotune.occupancy_bucket(1.0) == "1.0"
    assert autotune.occupancy_bucket(2.0) == "1.0"
    # the bucket IS the table key suffix the engine's static tune key uses
    assert autotune.entry_key("a", "decode", 0.3) == "a/decode/occ0.5"


# ---------------------------------------------------------------------------
# sweep mechanics (patched clock — no real timing in the fast tier)
# ---------------------------------------------------------------------------

_TINY = dict(block_sizes=(4,), rows=1, hkv=1, group=1, hd=8, max_blocks=2,
             iters=1, arch="test", interpret=True, verbose=False)


def test_sweep_rejects_sub_roofline_noise(monkeypatch):
    """A clock reporting impossibly fast times (below the bytes/FLOPs
    bound) must not elect a winner: every cell keeps the deterministic
    default and the table still validates."""
    monkeypatch.setattr(autotune, "_time_fn", lambda *a, **k: 0.0)
    table = autotune.sweep(**_TINY)
    autotune.validate_table(table)
    assert len(table["entries"]) == len(autotune.PHASES) * len(
        autotune.OCC_BUCKETS)
    for e in table["entries"].values():
        assert (e["num_splits"], e["q_tile"]) == (0, 0)
        assert e["tuned_us"] == e["default_us"]


def test_sweep_elects_measured_winner(monkeypatch):
    """With a deterministic decreasing clock the LAST candidate measured
    wins each cell, and tuned_us <= default_us holds on every entry by
    construction (the default is always a candidate).  The confirmation
    re-measure is pinned to uphold each win so the election logic is what
    is under test."""
    clock = iter(range(10**6, 0, -1))
    monkeypatch.setattr(autotune, "_time_fn",
                        lambda *a, **k: next(clock) * 1e-6)
    monkeypatch.setattr(
        autotune, "_measure_cfg",
        lambda phase, occ, cfg, **kw:
            1.0 if (cfg.num_splits, cfg.q_tile) != (0, 0) else 2.0)
    table = autotune.sweep(**_TINY)
    autotune.validate_table(table)  # includes tuned_us <= default_us
    assert any(e["num_splits"] > 0 or e["q_tile"] > 0
               for e in table["entries"].values())


def test_sweep_confirmation_rejects_noise_win(monkeypatch):
    """A candidate that wins the argmin but cannot reproduce its win in
    the head-to-head confirmation is discarded: the cell keeps the
    deterministic default (argmin winner's-curse guard)."""
    clock = iter(range(10**6, 0, -1))
    monkeypatch.setattr(autotune, "_time_fn",
                        lambda *a, **k: next(clock) * 1e-6)
    # confirmation: every geometry measures identically -> no win survives
    monkeypatch.setattr(autotune, "_measure_cfg",
                        lambda phase, occ, cfg, **kw: 5.0)
    table = autotune.sweep(**_TINY)
    autotune.validate_table(table)
    assert all((e["num_splits"], e["q_tile"]) == (0, 0)
               for e in table["entries"].values())


def test_check_regression_head_to_head(monkeypatch):
    """The nightly gate re-measures the committed geometry vs the default
    on this host and fails only when the tuned choice actually loses by
    more than the tolerance — never by comparing absolute times across
    runs (different hosts, and the committed argmin is biased low)."""
    key = autotune.entry_key("test", "decode", 0.25)
    committed = _table({key: _entry(num_splits=2)})
    times = {2: 10.0, 0: 12.0}  # tuned (splits=2) beats default (splits=0)
    monkeypatch.setattr(
        autotune, "_measure_cfg",
        lambda phase, occ, cfg, **kw: times[cfg.num_splits])
    assert autotune.check_regression(committed) == 0
    times[2] = 14.0  # tuned now loses to the default by > 10%
    assert autotune.check_regression(committed) == 1
    times[2] = 13.0  # loses, but within the 10% tolerance
    assert autotune.check_regression(committed) == 0
    # a cell whose committed geometry IS the default passes without
    # measuring at all (it cannot lose to itself)
    monkeypatch.setattr(autotune, "_measure_cfg",
                        lambda *a, **kw: pytest.fail("measured default"))
    plain = _table({key: _entry(num_splits=0, q_tile=0)})
    assert autotune.check_regression(plain) == 0


# ---------------------------------------------------------------------------
# ragged prefill/append: q-tiled kernel vs the gather oracle
# ---------------------------------------------------------------------------


def _prefill_case(seed, kv_lens, chunk, hkv, g, hd, bs, max_blocks):
    """Each row appends a `chunk`-query tail ending at its kv_len, through
    a per-row permuted block table sliced to the live width."""
    b = len(kv_lens)
    hq = hkv * g
    key = jax.random.key(seed)
    q = jax.random.normal(key, (b, chunk, hq, hd), jnp.float32)
    num_blocks = b * max_blocks
    k = jax.random.normal(
        jax.random.fold_in(key, 1), (hkv, num_blocks * bs, hd), jnp.float32
    )
    v = jax.random.normal(
        jax.random.fold_in(key, 2), (hkv, num_blocks * bs, hd), jnp.float32
    )
    rng = np.random.default_rng(seed)
    # one permutation across rows: tables are disjoint, so poisoning one
    # row's tail blocks can never alias another row's live blocks
    bt = rng.permutation(num_blocks).reshape(b, max_blocks)
    w = max(-(-kv // bs) for kv in kv_lens)
    qpos = jnp.asarray(
        [[kv - chunk + i for i in range(chunk)] for kv in kv_lens], jnp.int32
    )
    return q, k, v, jnp.asarray(bt[:, :w], jnp.int32), qpos


def _oracle(q, k, v, bt, qpos, *, scale, bs):
    cache = PagedKVCache(k=k, v=v, block_size=bs)
    view = paged_view(cache, bt)
    return _cached_attention(q * scale, view, qpos, NULL_ENV, softcap=0.0)


@pytest.mark.parametrize(
    "bs,g,chunk,q_tile",
    [
        (8, 1, 5, 2),  # chunk < block, tile straddles nothing
        (8, 2, 11, 4),  # GQA; chunk crosses a block boundary mid-tile
        (4, 2, 8, 3),  # tile size not a divisor of the chunk (ragged tail)
        (16, 1, 6, 6),  # one tile == whole chunk, big blocks
    ],
)
def test_prefill_kernel_matches_gather_oracle(bs, g, chunk, q_tile):
    hkv, hd, max_blocks = 2, 32, 8
    kv_lens = [max_blocks * bs, chunk + bs + 1, chunk]  # ragged histories
    q, k, v, bt, qpos = _prefill_case(0, kv_lens, chunk, hkv, g, hd, bs,
                                      max_blocks)
    scale = hd**-0.5
    got = paged_attention(q, k, v, bt, qpos, scale=scale, block_size=bs,
                          q_tile=q_tile, interpret=True)
    want = _oracle(q, k, v, bt, qpos, scale=scale, bs=bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_prefill_q_tile_invariance():
    """q_tile only re-tiles the grid: every query still runs the same
    f32 online softmax over the same blocks in the same order, so the
    output is invariant to the tile size (what makes tuned dispatch
    token-preserving in the engine)."""
    bs, hkv, g, hd, max_blocks, chunk = 8, 2, 2, 32, 8, 12
    kv_lens = [max_blocks * bs, chunk + 3]
    q, k, v, bt, qpos = _prefill_case(1, kv_lens, chunk, hkv, g, hd, bs,
                                      max_blocks)
    outs = [
        paged_attention(q, k, v, bt, qpos, scale=hd**-0.5, block_size=bs,
                        q_tile=qt, interpret=True)
        for qt in (0, 1, 3, 4, chunk)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-6, rtol=1e-6)


def test_prefill_kernel_int8_pool():
    """The q-tiled walk over an int8 pool dequantizes in VMEM to exactly
    the values the oracle sees on the host-dequantized pool."""
    bs, hkv, g, hd, max_blocks, chunk, q_tile = 8, 2, 2, 32, 8, 9, 4
    kv_lens = [max_blocks * bs, chunk + 2]
    q, k, v, bt, qpos = _prefill_case(2, kv_lens, chunk, hkv, g, hd, bs,
                                      max_blocks)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    got = paged_attention(q, k8, v8, bt, qpos, scale=hd**-0.5, block_size=bs,
                          q_tile=q_tile, k_scale=ks, v_scale=vs,
                          interpret=True)
    want = _oracle(q, dequantize_kv(k8, ks), dequantize_kv(v8, vs), bt, qpos,
                   scale=hd**-0.5, bs=bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_prefill_kernel_poisoned_pool_isolation():
    """NaNs in blocks past each row's causal extent (but inside the table
    width) never reach the q-tiled walk: each tile's ragged early exit
    stops at its own extent, so the output is bit-identical to the clean
    pool's."""
    bs, hkv, g, hd, max_blocks, chunk, q_tile = 4, 1, 2, 16, 8, 6, 2
    kv_lens = [max_blocks * bs, 7]  # row 1 uses 2 of the 8-wide table
    q, k, v, bt, qpos = _prefill_case(3, kv_lens, chunk, hkv, g, hd, bs,
                                      max_blocks)
    ref = paged_attention(q, k, v, bt, qpos, scale=hd**-0.5, block_size=bs,
                          q_tile=q_tile, interpret=True)
    poison_k, poison_v = np.array(k), np.array(v)
    for row, kv in enumerate(kv_lens):
        for blk in np.asarray(bt)[row, -(-kv // bs):]:
            poison_k[:, blk * bs:(blk + 1) * bs] = np.nan
            poison_v[:, blk * bs:(blk + 1) * bs] = np.nan
    got = paged_attention(q, jnp.asarray(poison_k), jnp.asarray(poison_v),
                          bt, qpos, scale=hd**-0.5, block_size=bs,
                          q_tile=q_tile, interpret=True)
    assert bool(jnp.all(jnp.isfinite(got)))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_prefill_kernel_blocks_model():
    """The analytical prefill bytes model kernel_bench gates: q_tile=0
    reads each block exactly once; smaller tiles re-stream early blocks
    but stop at their OWN extent, so the count stays below tiles * full."""
    bs, chunk, kv = 8, 16, 64
    assert prefill_kernel_blocks(kv, chunk, 0, bs) == -(-kv // bs)
    tiled = prefill_kernel_blocks(kv, chunk, 4, bs)
    assert -(-kv // bs) < tiled < 4 * -(-kv // bs)
    # append of 1 token (decode shape) degenerates to the decode model
    assert prefill_kernel_blocks(kv, 1, 0, bs) == -(-kv // bs)


# ---------------------------------------------------------------------------
# tuned dispatch: table-driven geometry is numerics-preserving
# ---------------------------------------------------------------------------


def test_ops_tuned_dispatch_matches_untuned(monkeypatch):
    """ops.paged_attention with a `phase` key consults the table and the
    tuned geometry (splits + q_tile) reproduces the untuned output —
    the contract that lets the engine flip tuning on without changing
    tokens."""
    table = _table(
        {autotune.entry_key(autotune.arch_key(), "verify", 0.125): _entry(
            num_splits=2, q_tile=2)}
    )
    monkeypatch.setattr(autotune, "get_table", lambda: table)
    assert autotune.get_config("verify", 0.1).num_splits == 2
    bs, hkv, g, hd, max_blocks, chunk = 8, 2, 2, 32, 16, 4
    kv_lens = [bs + chunk, chunk]
    q, k, v, bt, qpos = _prefill_case(4, kv_lens, chunk, hkv, g, hd, bs,
                                      max_blocks)
    want = ops.paged_attention(q, k, v, bt, qpos, scale=hd**-0.5,
                               block_size=bs)
    got = ops.paged_attention(q, k, v, bt, qpos, scale=hd**-0.5,
                              block_size=bs, phase="verify", occ=0.1)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), atol=1e-6,
                               rtol=1e-6)


def test_engine_tuned_bit_identity():
    """A paged engine with tuning on streams tokens bit-identical to
    tuning off (TP=1 fast-tier twin of distributed_impl.serve_tuned)."""
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=256
    ).replace(residual_mode=ResidualMode("ladder"))
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, 256, 19).tolist(),
                max_new_tokens=5, sampling=SamplingParams()),
        Request(rid=1, prompt=rng.integers(0, 256, 7).tolist(),
                max_new_tokens=4,
                sampling=SamplingParams(temperature=0.8, top_k=12, seed=3)),
    ]

    def run(tuned):
        eng = PagedServingEngine(
            cfg, params, batch_slots=2, s_max=48, block_size=8,
            max_prefill_tokens=16, use_pallas=True, tuned=tuned)
        for r in reqs:
            eng.submit(Request(rid=r.rid, prompt=list(r.prompt),
                               max_new_tokens=r.max_new_tokens,
                               sampling=r.sampling))
        return {rid: f.tokens for rid, f in eng.run().items()}

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# fused dequant + RMSNorm (the decode-path HBM round-trip cut)
# ---------------------------------------------------------------------------


def _pending_case(seed, tp, shape, d):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (*shape, d), jnp.float32)
    parts = jax.random.normal(jax.random.fold_in(key, 1), (tp, *shape, d),
                              jnp.float32)
    images, scales = quantize_kv(parts)
    weight = jax.random.normal(jax.random.fold_in(key, 2), (d,), jnp.float32)
    return x, images, scales, weight


@pytest.mark.parametrize("tp,shape,d", [(1, (2, 3), 32), (2, (5,), 64),
                                        (4, (3, 7), 16)])
def test_rmsnorm_dequant_kernel_matches_oracle(tp, shape, d):
    """Pallas fused dequant-sum+norm vs the jnp oracle: same f32
    source-ordered accumulate, same norm on the un-downcast sum —
    bit-identical UNDER JIT (how the engine runs both paths; eagerly the
    oracle's separate mul+add rounds twice where XLA emits one FMA, the
    same 1-ulp caveat tests/test_collectives.py documents), including
    padded row tails (row count not a multiple of the kernel's block)."""
    x, images, scales, weight = _pending_case(0, tp, shape, d)
    oracle = jax.jit(
        lambda *a: rmsnorm_dequant(*a, use_pallas=False))
    want = oracle(x, images, scales, weight)
    got = rmsnorm_dequant(x, images, scales, weight, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_rmsnorm_dequant_zero_scale_rows():
    """All-zero pending images (the engine's init_carry state for the
    first two sub-blocks) reduce the fused op to a plain rmsnorm."""
    from repro.models.layers import rmsnorm

    x, _, _, weight = _pending_case(1, 2, (4,), 32)
    images = jnp.zeros((2, 4, 32), jnp.int8)
    scales = jnp.zeros((2, 4), jnp.float32)
    got = rmsnorm_dequant(x, images, scales, weight, use_pallas=True)
    want = jax.jit(rmsnorm)(x, weight)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_fused_norm_bit_identity():
    """Ladder paged engine with comm_fuse_norm: the Pallas fused norm and
    the jnp oracle emit identical token streams; non-ladder modes refuse
    the flag (nothing is deferred to fuse)."""
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=256
    ).replace(residual_mode=ResidualMode("ladder"))
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, 256, 11).tolist(),
                max_new_tokens=5, sampling=SamplingParams()),
        Request(rid=1, prompt=rng.integers(0, 256, 6).tolist(),
                max_new_tokens=4,
                sampling=SamplingParams(temperature=0.9, top_k=16, seed=5)),
    ]

    def run(use_pallas):
        eng = PagedServingEngine(
            cfg, params, batch_slots=2, s_max=48, block_size=8,
            max_prefill_tokens=16, comm_fuse_norm=True,
            use_pallas=use_pallas)
        for r in reqs:
            eng.submit(Request(rid=r.rid, prompt=list(r.prompt),
                               max_new_tokens=r.max_new_tokens,
                               sampling=r.sampling))
        return {rid: f.tokens for rid, f in eng.run().items()}

    assert run(True) == run(False)

    std = cfg.replace(residual_mode=ResidualMode("standard"))
    with pytest.raises(NotImplementedError):
        PagedServingEngine(std, tfm.init_params(std, jax.random.key(0)),
                           batch_slots=2, s_max=48, block_size=8,
                           comm_fuse_norm=True)
