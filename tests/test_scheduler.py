"""Continuous-batching engine tests: scheduler slot lifecycle (pure host),
per-request sampler semantics, ragged KV-cache writes, and the headline
equivalence — a mixed-age continuous batch must emit bit-identical tokens to
each request decoded alone, for every residual topology."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, ResidualMode
from repro.models import transformer as tfm
from repro.parallel.collectives import NULL_ENV
from repro.serving import sampler
from repro.serving.kv_cache import cache_update, make_kv_cache
from repro.serving.scheduler import (ContinuousServingEngine, Request,
                                     SamplingParams, Scheduler, poisson_trace)


# ---------------------------------------------------------------------------
# scheduler unit tests (no jax)
# ---------------------------------------------------------------------------

def _req(rid, lp=4, gen=3, **kw):
    return Request(rid=rid, prompt=list(range(1, lp + 1)),
                   max_new_tokens=gen, **kw)


def test_scheduler_fifo_admission_respects_slot_pool():
    s = Scheduler(n_slots=2, s_max=32, max_prefills_per_step=4)
    for rid in range(4):
        s.submit(_req(rid))
    adm = s.admissions()
    assert [r.rid for _, r in adm] == [0, 1]          # FIFO, pool-bounded
    for slot, r in adm:
        s.start(slot, r, first_token=10)
    assert s.admissions() == []                       # pool full
    assert len(s.queue) == 2


def test_scheduler_prefill_rate_limit():
    s = Scheduler(n_slots=4, s_max=32, max_prefills_per_step=1)
    for rid in range(3):
        s.submit(_req(rid))
    assert len(s.admissions()) == 1                   # interleave with decode


def test_scheduler_eos_retirement_frees_slot():
    s = Scheduler(n_slots=1, s_max=32, eos_id=99)
    s.submit(_req(0, gen=100))
    s.submit(_req(1))
    [(slot, r0)] = s.admissions()
    assert not s.start(slot, r0, first_token=5)
    assert not s.observe(slot, 7)
    assert s.observe(slot, 99)                        # EOS retires
    fin = s.finished[-1]
    assert (fin.rid, fin.finish_reason, fin.tokens) == (0, "eos", [5, 7, 99])
    # freed slot is immediately reusable by the queued request
    [(slot2, r1)] = s.admissions()
    assert slot2 == slot and r1.rid == 1


def test_scheduler_length_cap_and_cache_full():
    s = Scheduler(n_slots=2, s_max=32, max_prefills_per_step=2)
    s.submit(_req(0, gen=1))
    s.submit(Request(rid=1, prompt=list(range(29)), max_new_tokens=50))
    adm = dict((r.rid, slot) for slot, r in s.admissions())
    assert s.start(adm[0], _req(0, gen=1), first_token=3)   # gen cap at 1
    assert s.finished[-1].finish_reason == "length"
    # rid=1: prompt 29, first token at pos 29; positions 30, 31 remain
    r1 = Request(rid=1, prompt=list(range(29)), max_new_tokens=50)
    assert not s.start(adm[1], r1, first_token=1)
    assert not s.observe(adm[1], 2)                   # pos 30
    assert s.observe(adm[1], 3)                       # pos 31 == s_max-1
    assert s.finished[-1].finish_reason == "cache_full"


def test_scheduler_rejects_oversized_prompt():
    s = Scheduler(n_slots=1, s_max=8)
    with pytest.raises(ValueError):
        s.submit(Request(rid=0, prompt=list(range(8)), max_new_tokens=1))
    with pytest.raises(ValueError):
        s.submit(Request(rid=1, prompt=[], max_new_tokens=1))


def test_poisson_trace_deterministic_and_sorted():
    a = poisson_trace(8, rate=10.0, seed=3)
    b = poisson_trace(8, rate=10.0, seed=3)
    assert [r.prompt for r in a] == [r.prompt for r in b]
    assert all(x.arrival < y.arrival for x, y in zip(a, a[1:]))


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def _rand_logits(b=5, v=64, seed=0):
    return jax.random.normal(jax.random.key(seed), (b, v)) * 3.0


def _keys(b, seed=0):
    return sampler.request_keys(jax.random.key(0),
                                jnp.arange(b, dtype=jnp.int32) + seed,
                                jnp.full((b,), 7, jnp.int32))


def test_sample_tokens_zero_temperature_matches_greedy():
    logits = _rand_logits()
    b = logits.shape[0]
    got = sampler.sample_tokens(logits, NULL_ENV, 60, _keys(b),
                                jnp.zeros((b,)), jnp.zeros((b,), jnp.int32),
                                jnp.ones((b,)))
    want = sampler.greedy(logits, NULL_ENV, true_vocab=60)
    np.testing.assert_array_equal(got, want)


def test_sample_tokens_top_k_one_matches_greedy():
    logits = _rand_logits(seed=1)
    b = logits.shape[0]
    got = sampler.sample_tokens(logits, NULL_ENV, 60, _keys(b),
                                jnp.full((b,), 1.3),
                                jnp.ones((b,), jnp.int32),   # top_k = 1
                                jnp.ones((b,)))
    np.testing.assert_array_equal(
        got, sampler.greedy(logits, NULL_ENV, true_vocab=60))


def test_sample_tokens_tiny_top_p_matches_greedy():
    logits = _rand_logits(seed=2)
    b = logits.shape[0]
    got = sampler.sample_tokens(logits, NULL_ENV, 60, _keys(b),
                                jnp.full((b,), 0.9),
                                jnp.zeros((b,), jnp.int32),
                                jnp.full((b,), 1e-6))        # nucleus = top-1
    np.testing.assert_array_equal(
        got, sampler.greedy(logits, NULL_ENV, true_vocab=60))


def test_sample_tokens_fixed_seed_deterministic():
    logits = _rand_logits(seed=3)
    b = logits.shape[0]
    args = (jnp.full((b,), 1.0), jnp.full((b,), 8, jnp.int32),
            jnp.full((b,), 0.95))
    a = sampler.sample_tokens(logits, NULL_ENV, 64, _keys(b), *args)
    c = sampler.sample_tokens(logits, NULL_ENV, 64, _keys(b), *args)
    np.testing.assert_array_equal(a, c)
    d = sampler.sample_tokens(logits, NULL_ENV, 64, _keys(b, seed=100), *args)
    assert not np.array_equal(np.asarray(a), np.asarray(d))


def test_sample_tokens_respects_top_k_support():
    """With top_k=2 every sampled token is one of the two best logits."""
    logits = _rand_logits(b=64, seed=4)
    b = logits.shape[0]
    got = np.asarray(sampler.sample_tokens(
        logits, NULL_ENV, 64, _keys(b), jnp.full((b,), 2.0),
        jnp.full((b,), 2, jnp.int32), jnp.ones((b,))))
    top2 = np.argsort(-np.asarray(logits), axis=-1)[:, :2]
    assert all(got[i] in top2[i] for i in range(b))


def test_sample_tokens_never_emits_padded_vocab():
    logits = _rand_logits(b=32, v=64, seed=5)
    b = logits.shape[0]
    got = np.asarray(sampler.sample_tokens(
        logits, NULL_ENV, 40, _keys(b), jnp.full((b,), 5.0),
        jnp.zeros((b,), jnp.int32), jnp.ones((b,))))
    assert got.max() < 40


# ---------------------------------------------------------------------------
# ragged cache semantics
# ---------------------------------------------------------------------------

def test_ragged_cache_per_row_writes_and_drops():
    cache = make_kv_cache(2, 8, 1, 4, jnp.float32, ragged=True)
    assert cache.slot_pos.shape == (2, 8)
    # row 0 decodes at position 5, row 1 is inactive (position -1)
    kv = jnp.stack([jnp.full((1, 1, 4), 1.0), jnp.full((1, 1, 4), 2.0)])
    pos = jnp.asarray([[5], [-1]], jnp.int32)
    cache = cache_update(cache, kv, kv, pos, NULL_ENV)
    sp = np.asarray(cache.slot_pos)
    assert sp[0, 5] == 5 and (sp[0, :5] == -1).all()
    assert (sp[1] == -1).all()                        # dropped write
    assert float(cache.k[0, 0, 5, 0]) == 1.0
    assert float(np.abs(np.asarray(cache.k[1])).sum()) == 0.0


def test_ragged_prefill_padding_dropped():
    """Right-padded single-request prefill: positions -1 beyond the real
    length must leave the tail slots empty."""
    cache = make_kv_cache(1, 8, 1, 4, jnp.float32, ragged=True)
    s, real = 6, 4
    kv = jnp.arange(s, dtype=jnp.float32)[None, :, None, None] * \
        jnp.ones((1, s, 1, 4))
    ar = jnp.arange(s)
    pos = jnp.where(ar < real, ar, -1)[None]
    cache = cache_update(cache, kv, kv, pos, NULL_ENV)
    sp = np.asarray(cache.slot_pos[0])
    assert sp[:real].tolist() == [0, 1, 2, 3] and (sp[real:] == -1).all()


# ---------------------------------------------------------------------------
# continuous-batching equivalence (the headline invariant)
# ---------------------------------------------------------------------------

def _tiny_cfg(mode, arch="stablelm-3b"):
    import dataclasses
    cfg = REGISTRY[arch].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=256
    ).replace(residual_mode=ResidualMode(mode))
    if cfg.moe is not None:
        # bit-equivalence needs drop-free routing: finite expert capacity
        # couples requests across the batch (DESIGN.md §Serving)
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0, aux_loss_weight=0.0))
    return cfg


def _requests(vocab, rng):
    cases = [(5, 6, SamplingParams()),
             (11, 4, SamplingParams(temperature=0.8, top_k=20, top_p=0.9,
                                    seed=7)),
             (19, 5, SamplingParams(temperature=1.2, seed=3))]
    return [Request(rid=i, prompt=rng.integers(0, vocab, lp).tolist(),
                    max_new_tokens=g, sampling=s)
            for i, (lp, g, s) in enumerate(cases)]


def _clone(r):
    return Request(rid=r.rid, prompt=list(r.prompt),
                   max_new_tokens=r.max_new_tokens, sampling=r.sampling)


@pytest.mark.parametrize("arch,mode", [
    ("stablelm-3b", "ladder"), ("stablelm-3b", "standard"),
    ("stablelm-3b", "desync2"),
    ("gemma3-4b", "ladder"),     # ragged RING caches (window 16 < prompts)
    ("rwkv6-7b", "ladder"),      # recurrent state slot reset/reuse
    ("deepseek-v2-lite-16b", "ladder"),  # ragged MLA latent cache
])
def test_continuous_batch_matches_isolated_decoding(arch, mode):
    """Different prompt lengths, different arrival steps, mixed greedy and
    sampled requests, more requests than slots: the continuous engine must
    emit exactly the tokens each request gets when decoded alone."""
    cfg = _tiny_cfg(mode, arch)
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = _requests(cfg.vocab_size, rng)

    iso = {}
    for r in reqs:
        e = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48)
        e.submit(_clone(r))
        iso[r.rid] = e.run()[r.rid].tokens

    # 2 slots for 3 requests, third arrives only after the first step
    eng = ContinuousServingEngine(cfg, params, batch_slots=2, s_max=48)
    eng.submit(_clone(reqs[0]))
    eng.submit(_clone(reqs[1]))
    eng.step()
    eng.submit(_clone(reqs[2]))
    cont = eng.run()

    assert set(cont) == set(iso)
    for rid, toks in iso.items():
        assert cont[rid].tokens == toks, rid


@pytest.mark.parametrize("arch", ["stablelm-3b", "rwkv6-7b"])
def test_continuous_engine_matches_full_forward_reference(arch):
    """Anchor against the raw model, not just against another engine run:
    greedy engine output must equal argmax decoding via full forwards over
    growing prefixes.  Catches whole-engine distortions that symmetric
    continuous-vs-isolated comparisons cannot (e.g. prompt padding leaking
    into recurrent state — both engine runs would be corrupted alike)."""
    cfg = _tiny_cfg("ladder", arch)
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 7).tolist()  # pads to bucket 16
    gen = 5

    e = ContinuousServingEngine(cfg, params, batch_slots=2, s_max=32)
    e.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=gen))
    got = e.run()[0].tokens

    toks, want = list(prompt), []
    for _ in range(gen):
        hidden, _, _ = tfm.forward(cfg, params, jnp.asarray(toks)[None],
                                   NULL_ENV)
        logits = tfm.logits_shard(cfg, params, hidden[:, -1:])[:, 0]
        lf = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size,
                       logits.astype(jnp.float32), -1e30)
        nxt = int(jnp.argmax(lf, -1)[0])
        want.append(nxt)
        toks.append(nxt)
    assert got == want


def test_continuous_engine_eos_truncates():
    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    req = _requests(cfg.vocab_size, rng)[0]

    e = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48)
    e.submit(_clone(req))
    full = e.run()[req.rid].tokens
    assert len(full) >= 3

    # pretend token j is the EOS id, for a j whose value first appears there
    j = next(i for i in range(1, len(full)) if full[i] not in full[:i])
    e2 = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48,
                                 eos_id=full[j])
    e2.submit(_clone(req))
    fin = e2.run()[req.rid]
    assert fin.finish_reason == "eos"
    assert fin.tokens == full[:j + 1]


def test_continuous_engine_rejects_encoder_models():
    cfg = REGISTRY["whisper-small"].reduced(n_layers=2)
    with pytest.raises(NotImplementedError):
        ContinuousServingEngine(cfg, params=None, batch_slots=1, s_max=16)
