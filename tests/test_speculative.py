"""Speculative-decoding tests (DESIGN.md §Speculative decoding).

Layers, bottom-up:

* NgramDrafter.lookup — pure host prompt-lookup semantics (no jax).
* sampler.rejection_sample — the standard stochastic accept rule matches
  the target distribution empirically (standalone, no engine).
* PagedScheduler.ensure_blocks_through / rollback_blocks — speculative
  block materialisation and tail rollback, host-only.
* SpeculativePagedEngine — the headline equivalence: with EITHER drafter
  (ngram self-speculation or a small draft model) and for
  ladder/standard/desync2, the speculative engine emits token streams
  bit-identical to the non-speculative engines under greedy AND seeded
  sampling; on repetitive greedy traffic it measurably accepts drafts
  (tokens_per_forward > 1).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, ResidualMode
from repro.models import transformer as tfm
from repro.serving import sampler
from repro.serving.kv_cache import BlockAllocator, PrefixCache
from repro.serving.scheduler import (
    ContinuousServingEngine,
    PagedScheduler,
    PagedServingEngine,
    Request,
    SamplingParams,
)
from repro.serving.speculative import (
    DraftModelDrafter,
    NgramDrafter,
    SpeculativePagedEngine,
)


# ---------------------------------------------------------------------------
# ngram drafter (no jax)
# ---------------------------------------------------------------------------


def test_ngram_lookup_prefers_longest_and_most_recent_match():
    d = NgramDrafter(max_ngram=3, min_ngram=1)
    # suffix [7, 8] occurs twice; the most recent occurrence (followed by
    # 99) must win over the older one (followed by 11)
    ctx = [7, 8, 11, 5, 7, 8, 99, 42, 7, 8]
    assert d.lookup(ctx, 2) == [99, 42]
    # longest n wins: 3-gram match beats the 1-gram fallback
    ctx2 = [1, 2, 3, 50, 9, 1, 2, 3, 60, 61, 1, 2, 3]
    assert d.lookup(ctx2, 3) == [60, 61, 1]


def test_ngram_lookup_misses_return_empty():
    d = NgramDrafter(max_ngram=2, min_ngram=2)
    assert d.lookup([1, 2, 3, 4], 4) == []       # no repeated 2-gram
    assert d.lookup([5], 4) == []                # context shorter than n
    with pytest.raises(ValueError):
        NgramDrafter(max_ngram=1, min_ngram=2)


def test_ngram_propose_respects_budgets():
    d = NgramDrafter()
    ctx = {0: [3, 4, 3, 4, 3, 4], 1: [1, 2, 3]}
    out = d.propose([0, 1], ctx, {0: 2, 1: 0})
    assert out[0] == [3, 4] and out[1] == []


# ---------------------------------------------------------------------------
# rejection-sampling accept rule (standalone; empirical)
# ---------------------------------------------------------------------------


def test_rejection_sample_matches_target_distribution():
    """Emitted tokens are exact samples from p even when the draft
    distribution q is badly wrong — the Leviathan/Chen guarantee."""
    v = 8
    rng = np.random.default_rng(0)
    p_logits = jnp.asarray(rng.normal(0, 1.5, (v,)), jnp.float32)
    q_logits = jnp.asarray(rng.normal(0, 1.5, (v,)), jnp.float32)
    p = np.asarray(jax.nn.softmax(p_logits))
    q = np.asarray(jax.nn.softmax(q_logits))

    n = 20000
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(42), i))(
        jnp.arange(n))
    # drafts drawn from q (the rule assumes q(draft) > 0)
    draft = jax.vmap(
        lambda k: jax.random.categorical(jax.random.fold_in(k, 99),
                                         q_logits))(keys).astype(jnp.int32)
    accepted, toks = sampler.rejection_sample(
        keys, jnp.broadcast_to(p_logits, (n, v)),
        jnp.broadcast_to(q_logits, (n, v)), draft)

    emp = np.bincount(np.asarray(toks), minlength=v) / n
    tv = 0.5 * np.abs(emp - p).sum()
    assert tv < 0.02, f"TV(emitted, target) = {tv:.4f}"
    # acceptance rate ~= sum_x min(p(x), q(x))
    want_acc = np.minimum(p, q).sum()
    got_acc = float(jnp.mean(accepted))
    assert abs(got_acc - want_acc) < 0.02
    # accepted tokens really are the drafts
    assert np.array_equal(np.asarray(toks)[np.asarray(accepted)],
                          np.asarray(draft)[np.asarray(accepted)])


def test_rejection_sample_identical_distributions_always_accept():
    v, n = 6, 64
    logits = jnp.asarray(np.linspace(-1, 1, v), jnp.float32)
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(0), i))(
        jnp.arange(n))
    draft = jnp.zeros((n,), jnp.int32)
    accepted, toks = sampler.rejection_sample(
        keys, jnp.broadcast_to(logits, (n, v)),
        jnp.broadcast_to(logits, (n, v)), draft)
    assert bool(jnp.all(accepted)) and bool(jnp.all(toks == draft))


# ---------------------------------------------------------------------------
# scheduler: speculative block materialisation + rollback (no jax)
# ---------------------------------------------------------------------------


def _drive_prefill(s, tok=7):
    for slot, chunk, start in s.prefill_work():
        seq = s.slots[slot]
        s.chunk_filled(slot, len(chunk))
        if start + len(chunk) == len(seq.request.prompt):
            s.start_decode(slot, tok)


def test_ensure_blocks_through_and_rollback_restore_reservation():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    s = PagedScheduler(1, 32, alloc, prefix_cache=PrefixCache())
    s.submit(Request(rid=0, prompt=list(range(6)), max_new_tokens=12))
    s.admissions()
    _drive_prefill(s)
    seq = s.slots[0]
    assert seq.pos == 6 and len(seq.blocks) == 2   # prompt: 2 blocks
    free0, res0 = alloc.num_free(), s.total_reserved

    # a verify step writing 4 draft positions past pos spans 2 new blocks
    s.ensure_blocks_through(0, seq.pos + 4)
    assert len(seq.blocks) == 3 and s.total_reserved == res0 - 1
    # all drafts rejected: pos only advances by 1 (the corrected token)
    s.observe(0, 9)
    assert seq.pos == 7
    freed = s.rollback_blocks(0)
    assert freed == 1                               # block for pos 8..11
    assert alloc.num_free() == free0 and s.total_reserved == res0
    assert len(seq.blocks) == 2

    # full acceptance: pos advances past the materialised tail, nothing
    # to roll back
    s.ensure_blocks_through(0, seq.pos + 4)
    for t in range(4):
        s.observe(0, 10 + t)
    assert s.rollback_blocks(0) == 0


def test_rollback_never_touches_prompt_or_prefix_blocks():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    pc = PrefixCache()
    s = PagedScheduler(2, 32, alloc, prefix_cache=pc)
    shared = list(range(100, 108))                  # 2 full cached blocks
    s.submit(Request(rid=0, prompt=shared + [1], max_new_tokens=1))
    s.admissions()
    _drive_prefill(s)                               # retires, registers
    s.submit(Request(rid=1, prompt=shared + [2], max_new_tokens=6))
    s.admissions()
    _drive_prefill(s)
    seq = s.slots[0]
    assert seq.num_cached == 8                      # prefix hit engaged
    s.ensure_blocks_through(0, seq.pos + 3)
    s.observe(0, 5)
    s.rollback_blocks(0)
    # the shared prefix blocks are still owned and still registered
    assert all(alloc.refcount(b) >= 1 for b in seq.blocks[:2])
    assert all(pc.contains_block(b) for b in seq.blocks[:2])


# ---------------------------------------------------------------------------
# engine equivalences (the acceptance invariants)
# ---------------------------------------------------------------------------


def _tiny_cfg(mode):
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=256
    )
    return cfg.replace(residual_mode=ResidualMode(mode))


def _params(cfg):
    return tfm.init_params(cfg, jax.random.key(0))


def _draft(cfg):
    dcfg = cfg.reduced(n_layers=1)
    return dcfg, tfm.init_params(dcfg, jax.random.key(7))


def _mixed_trace(vocab, rng):
    """Shared prefix, variable prompts, greedy AND seeded sampled rows."""
    shared = rng.integers(0, vocab, 16).tolist()
    cases = [
        (shared + rng.integers(0, vocab, 5).tolist(), 7, SamplingParams()),
        (
            shared + rng.integers(0, vocab, 9).tolist(),
            5,
            SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=7),
        ),
        (
            rng.integers(0, vocab, 7).tolist(),
            6,
            SamplingParams(temperature=1.2, seed=3),
        ),
        (shared + rng.integers(0, vocab, 3).tolist(), 5, SamplingParams()),
    ]
    return [
        Request(rid=i, prompt=p, max_new_tokens=g, sampling=sp)
        for i, (p, g, sp) in enumerate(cases)
    ]


def _clone(r):
    return Request(
        rid=r.rid,
        prompt=list(r.prompt),
        max_new_tokens=r.max_new_tokens,
        sampling=r.sampling,
    )


def _serve_staggered(engine, reqs):
    engine.submit(_clone(reqs[0]))
    engine.submit(_clone(reqs[1]))
    engine.step()
    for r in reqs[2:]:
        engine.submit(_clone(r))
    return engine.run()


def _spec_engine(cfg, params, spec_mode, spec_k=3):
    kw = {}
    if spec_mode == "draft":
        kw["draft_cfg"], kw["draft_params"] = _draft(cfg)
    return SpeculativePagedEngine(
        cfg,
        params,
        batch_slots=2,
        s_max=48,
        block_size=8,
        max_prefill_tokens=16,
        spec_mode=spec_mode,
        spec_k=spec_k,
        **kw,
    )


@pytest.mark.parametrize("spec_mode", ["ngram", "draft"])
@pytest.mark.parametrize("mode", ["ladder", "standard", "desync2"])
def test_spec_engine_matches_plain_decode(mode, spec_mode):
    """Mixed staggered trace (greedy + seeded sampling, shared prefix):
    the speculative engine must emit bit-identical token streams to the
    ragged oracle with either drafter, for every residual mode."""
    cfg = _tiny_cfg(mode)
    params = _params(cfg)
    reqs = _mixed_trace(cfg.vocab_size, np.random.default_rng(0))

    ragged = ContinuousServingEngine(cfg, params, batch_slots=2, s_max=48)
    want = _serve_staggered(ragged, reqs)

    spec = _spec_engine(cfg, params, spec_mode)
    got = _serve_staggered(spec, reqs)

    assert set(got) == set(want)
    for rid in want:
        assert got[rid].tokens == want[rid].tokens, rid
    st = spec.stats()
    assert st["verify_forwards"] > 0
    assert st["tokens_per_forward"] >= 1.0


def test_spec_accepts_drafts_on_repetitive_greedy_traffic():
    """Greedy decode of a tiny random-init model loops; prompt-lookup
    drafting must convert that into multi-token verify steps."""
    cfg = _tiny_cfg("ladder")
    params = _params(cfg)
    rng = np.random.default_rng(1)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, 12).tolist(),
            max_new_tokens=24,
            sampling=SamplingParams(),
        )
        for i in range(2)
    ]

    plain = PagedServingEngine(cfg, params, batch_slots=2, s_max=64,
                               block_size=8)
    for r in reqs:
        plain.submit(_clone(r))
    want = plain.run()

    spec = SpeculativePagedEngine(cfg, params, batch_slots=2, s_max=64,
                                  block_size=8, spec_mode="ngram", spec_k=4)
    for r in reqs:
        spec.submit(_clone(r))
    got = spec.run()

    for rid in want:
        assert got[rid].tokens == want[rid].tokens
    st = spec.stats()
    assert st["accept_rate"] > 0
    assert st["tokens_per_forward"] > 1.0
    # speculation must SAVE forwards vs one decode per token
    n_tok = sum(len(f.tokens) for f in got.values())
    assert st["verify_forwards"] < n_tok - len(got)  # strictly fewer


def test_spec_budget_clamps_to_remaining_and_smax():
    """max_new_tokens=1 leaves zero draft budget (verify == plain decode);
    requests near s_max never write past slot s_max - 2."""
    cfg = _tiny_cfg("ladder")
    params = _params(cfg)
    rng = np.random.default_rng(2)
    spec = SpeculativePagedEngine(cfg, params, batch_slots=1, s_max=32,
                                  block_size=8, spec_mode="ngram", spec_k=4)
    spec.submit(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, 6).tolist(),
        max_new_tokens=1, sampling=SamplingParams()))
    # long request that retires on cache_full: budget shrinks to 0 at the
    # edge instead of writing out of range
    spec.submit(Request(
        rid=1, prompt=rng.integers(0, cfg.vocab_size, 20).tolist(),
        max_new_tokens=64, sampling=SamplingParams()))
    fin = spec.run()
    assert len(fin[0].tokens) == 1
    assert fin[1].finish_reason == "cache_full"
    assert spec.drafted == spec.accepted or spec.drafted >= 0  # ran clean


def test_spec_engine_rejects_bad_args():
    cfg = _tiny_cfg("ladder")
    params = _params(cfg)
    with pytest.raises(ValueError):
        SpeculativePagedEngine(cfg, params, batch_slots=1, s_max=16,
                               spec_mode="ngram", spec_k=0)
    with pytest.raises(ValueError):
        SpeculativePagedEngine(cfg, params, batch_slots=1, s_max=16,
                               spec_mode="wat")
    with pytest.raises(ValueError):
        SpeculativePagedEngine(cfg, params, batch_slots=1, s_max=16,
                               spec_mode="draft")   # no draft model given
    bad_cfg = cfg.reduced(n_layers=1).replace(vocab_size=128)
    with pytest.raises(ValueError):
        DraftModelDrafter(cfg, bad_cfg, None, batch_slots=1, s_max=16,
                          spec_k=2)
