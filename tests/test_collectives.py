"""Fast-tier comm-correctness harness for the overlapped TP AllReduce.

The device ring runs under shard_map in tests/distributed_impl.py
(``serve_comm`` group); this file pins everything that does not need a
multi-device mesh:

* the ``CommConfig`` / ``AxisEnv.psum_model`` dispatch seam (validation,
  raise-on-invalid — including the previously-silent unsharded path),
* the chunk schedule (``chunk_bounds`` cover/no-overlap/ragged/clamp),
* the host-side simulators as oracle: ring == psum across chunk counts x
  dtypes x ragged shapes x tp, with cross-shard bit-identity,
* the compressed ring's quantization error bound,
* the Pallas masked dequant-accumulate kernel in interpret mode
  (poisoned-pad-tail isolation, chunk-boundary off-by-ones).

``dequant_accumulate`` comparisons use tight allclose, NOT bit-equality:
when the valid-mask constant-folds to all-true XLA may fuse the
multiply+add into an FMA (one rounding instead of two), a <=1-ulp
difference vs the unfused reference.  Cross-shard identity is unaffected
because every shard runs the same fused program.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.kernels.comm import dequant_accumulate
from repro.parallel import compat
from repro.parallel.collectives import NULL_ENV, AxisEnv
from repro.parallel.overlap import (
    COMM_MODES,
    SYNC,
    CommConfig,
    chunk_bounds,
    compressed_ring_all_reduce,
    ring_all_reduce,
    simulate_compressed_all_reduce,
    simulate_ring_all_reduce,
)
from repro.quant import BLOCK, dequantize_int8, quantize_int8


# ---- CommConfig / dispatch seam -------------------------------------------

def test_comm_config_defaults_to_sync():
    assert SYNC.mode == "sync" and CommConfig().mode == "sync"
    assert "sync" in COMM_MODES and len(COMM_MODES) == 3


@pytest.mark.parametrize("bad", ["", "async", "SYNC", "ring"])
def test_comm_config_rejects_invalid_mode(bad):
    with pytest.raises(ValueError, match="invalid comm mode"):
        CommConfig(mode=bad)


@pytest.mark.parametrize("chunks", [0, -1])
def test_comm_config_rejects_invalid_chunks(chunks):
    with pytest.raises(ValueError, match="chunks"):
        CommConfig(chunks=chunks)


def test_psum_model_raises_on_invalid_mode_even_unsharded():
    """The satellite fix: an env with a bogus mode must raise at the one
    dispatch point instead of silently falling through to sync — even on
    the unsharded (model=None) degenerate path, where the old code
    returned x before ever looking at the mode."""
    env = AxisEnv()  # unsharded
    object.__setattr__(env.comm, "mode", "bogus")  # bypass __post_init__
    with pytest.raises(ValueError, match="invalid comm mode 'bogus'"):
        env.psum_model(jnp.ones((3,)))


@pytest.mark.parametrize("mode", COMM_MODES)
def test_psum_model_identity_unsharded(mode):
    """model=None => every valid mode is exactly the identity."""
    env = AxisEnv(comm=CommConfig(mode=mode))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 5)), jnp.float32)
    np.testing.assert_array_equal(env.psum_model(x), x)


@pytest.mark.parametrize("mode", COMM_MODES)
def test_reduce_block_output_unsharded_dispatch(mode):
    """reduce_block_output is residual.py's single call site; unsharded it
    must be the identity for every mode (SP off and on — SP needs a model
    axis to do anything)."""
    x = jnp.ones((1, 4, 8))
    for sp in (False, True):
        env = AxisEnv(sp=sp, comm=CommConfig(mode=mode))
        np.testing.assert_array_equal(env.reduce_block_output(x), x)
    np.testing.assert_array_equal(NULL_ENV.reduce_block_output(x), x)


# ---- chunk schedule --------------------------------------------------------

@pytest.mark.parametrize("n,chunks", [(1, 1), (7, 3), (8, 3), (9, 3),
                                      (64, 4), (5, 8), (256, 1), (33, 5)])
def test_chunk_bounds_cover_exactly(n, chunks):
    spans = chunk_bounds(n, chunks)
    assert len(spans) == min(chunks, n)
    # contiguous, non-overlapping, in order, covering [0, n)
    pos = 0
    for start, size in spans:
        assert start == pos and size >= 1
        pos += size
    assert pos == n
    # ragged only in the last span
    sizes = [s for _, s in spans]
    assert all(s == sizes[0] for s in sizes[:-1])
    assert sizes[-1] <= sizes[0]


def test_chunk_bounds_degenerate():
    assert chunk_bounds(0, 4) == []
    assert chunk_bounds(-3, 4) == []
    assert chunk_bounds(5, 1) == [(0, 5)]


# ---- single-device ring (degenerate tp=1 path, real shard_map) ------------

def test_single_device_ring_is_identity():
    """tp=1 is the documented degenerate path: both rings return x
    bit-unchanged (no wire traffic, no quantization error)."""
    mesh = compat.make_mesh((1,), ("model",))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 7, 24)),
                    jnp.float32)

    for fn in (lambda v: ring_all_reduce(v, "model", chunks=3),
               lambda v: compressed_ring_all_reduce(v, "model", chunks=3)):
        wrapped = compat.shard_map(fn, mesh, P(), P())
        with compat.set_mesh(mesh):
            out = jax.jit(wrapped)(x)
        np.testing.assert_array_equal(out, x)


# ---- simulator sweep (the fast-tier oracle for the device path) -----------

SHAPES = [(1, 1, 64),    # decode: one token
          (2, 16, 48),   # small prefill
          (1, 7, 33)]    # ragged: n not divisible by anything convenient


@pytest.mark.parametrize("tp", [2, 3, 4])
@pytest.mark.parametrize("chunks", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
def test_simulated_ring_matches_psum(tp, chunks, dtype, shape):
    """The ring must equal the psum: bit-exact at tp=2 (single commutative
    add), within rounding above; and every shard's row must be
    bit-identical (source-ordered fixed-association summation)."""
    rng = np.random.default_rng(hash((tp, chunks, shape)) % 2**32)
    shards = jnp.asarray(rng.normal(size=(tp, *shape)), dtype)
    out = simulate_ring_all_reduce(shards, chunks=chunks)
    assert out.dtype == dtype
    # cross-shard bit-identity
    for i in range(1, tp):
        np.testing.assert_array_equal(out[0], out[i])
    want = jnp.sum(shards.astype(jnp.float32), axis=0)
    if tp == 2:
        np.testing.assert_array_equal(
            out[0].astype(jnp.float32),
            want if dtype == jnp.float32
            else (shards[0] + shards[1]).astype(jnp.float32))
    else:
        tol = 1e-6 if dtype == jnp.float32 else 1e-1
        np.testing.assert_allclose(out[0].astype(jnp.float32), want,
                                   rtol=tol, atol=tol)


def test_simulated_ring_chunk_count_invariant():
    """Chunking is a schedule choice, not a numerics choice: any chunk
    count gives the bit-same result (per-chunk sums are independent)."""
    rng = np.random.default_rng(7)
    shards = jnp.asarray(rng.normal(size=(4, 3, 50)), jnp.float32)
    ref = simulate_ring_all_reduce(shards, chunks=1)
    for chunks in (2, 3, 7, 50, 999):
        np.testing.assert_array_equal(
            simulate_ring_all_reduce(shards, chunks=chunks), ref)


@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("chunks", [1, 3])
def test_simulated_compressed_bounded_error(tp, chunks):
    """Compressed ring: all rows bit-identical, and the per-element error
    vs the fp32 sum is bounded by sum_j scale_j / 2 (each source
    contributes at most half a quant step per element)."""
    rng = np.random.default_rng(tp * 10 + chunks)
    shards = jnp.asarray(rng.normal(size=(tp, 2, 5, 40)), jnp.float32)
    out = simulate_compressed_all_reduce(shards, chunks=chunks)
    for i in range(1, tp):
        np.testing.assert_array_equal(out[0], out[i])
    want = jnp.sum(shards, axis=0)
    flat = shards.reshape(tp, -1)
    n = flat.shape[1]
    bound = np.zeros(n, np.float64)
    for start, size in chunk_bounds(n, chunks):
        for j in range(tp):
            _, scale = quantize_int8(flat[j, start:start + size])
            per_elem = jnp.repeat(scale, BLOCK)[:size]
            bound[start:start + size] += 0.5 * np.asarray(per_elem)
    err = np.abs(np.asarray(out[0] - want)).reshape(-1)
    assert np.all(err <= bound + 1e-6), float((err - bound).max())


# ---- Pallas dequant-accumulate kernel (interpret mode) --------------------

def _ref_dequant_acc(acc, q, scale, valid):
    return acc + dequantize_int8(q, scale, (int(valid),))


@pytest.mark.parametrize("valid", [1, BLOCK - 1, BLOCK, BLOCK + 1,
                                   2 * BLOCK - 1, 2 * BLOCK])
def test_dequant_accumulate_chunk_boundaries(valid):
    """Off-by-one sweep around the quant-block boundary.  allclose, not
    bit-equality: the fused multiply-add may round once where the
    reference rounds twice (<= 1 ulp)."""
    rng = np.random.default_rng(valid)
    blocks = -(-valid // BLOCK)
    q = jnp.asarray(rng.integers(-127, 128, size=(blocks, BLOCK)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.01, 2.0, size=(blocks,)), jnp.float32)
    acc = jnp.asarray(rng.normal(size=(valid,)), jnp.float32)
    got = dequant_accumulate(acc, q, scale, valid, interpret=True)
    want = _ref_dequant_acc(acc, q, scale, valid)
    assert got.shape == (valid,)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("valid", [1, BLOCK - 1, BLOCK + 1, 2 * BLOCK - 5])
def test_dequant_accumulate_isolates_poisoned_pad(valid):
    """The wire buffer's pad tail may hold ANYTHING (stale chunk, 1e38,
    NaN int8 garbage / NaN scales on fully-pad blocks) — the in-kernel
    mask must keep it all out of the sum."""
    rng = np.random.default_rng(valid + 1000)
    blocks = -(-valid // BLOCK) + 1  # one extra, fully-pad quant block
    q = np.asarray(rng.integers(-127, 128, size=(blocks, BLOCK)), np.int8)
    scale = np.asarray(rng.uniform(0.01, 2.0, size=(blocks,)), np.float32)
    # clean reference BEFORE poisoning
    want = _ref_dequant_acc(
        jnp.zeros((valid,), jnp.float32),
        jnp.asarray(q[:blocks - 1]), jnp.asarray(scale[:blocks - 1]), valid)
    # poison: garbage q beyond `valid` inside the last REAL block (its
    # scale must stay sane — real lanes share it), then NaN/huge scale on
    # the fully-pad block
    last_real = blocks - 2
    tail_start = valid - last_real * BLOCK
    q[last_real, tail_start:] = 127
    q[blocks - 1, :] = -128
    scale[blocks - 1] = np.nan
    got = dequant_accumulate(jnp.zeros((valid,), jnp.float32),
                             jnp.asarray(q), jnp.asarray(scale), valid,
                             interpret=True)
    assert np.all(np.isfinite(np.asarray(got)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_dequant_accumulate_rejects_bad_valid():
    q = jnp.zeros((2, BLOCK), jnp.int8)
    scale = jnp.zeros((2,), jnp.float32)
    with pytest.raises(ValueError):
        dequant_accumulate(jnp.zeros((0,)), q, scale, 0, interpret=True)
    with pytest.raises(ValueError):
        dequant_accumulate(jnp.zeros((2 * BLOCK + 1,)), q, scale,
                           2 * BLOCK + 1, interpret=True)
