"""Shared fixtures and test tiering.

Tiers (markers registered here AND in pyproject.toml so either entry point
works):

  fast tier   PYTHONPATH=src python -m pytest -m "not slow" -q
              single-process tests only; a few minutes on one CPU.  This is
              the canonical pre-merge check — scripts/ci.sh runs exactly it.
  full tier   PYTHONPATH=src python -m pytest -q
              adds the `slow` suites: multi-device subprocess groups
              (tests/test_distributed.py) that spawn 4 fake XLA devices.

NOTE: no XLA_FLAGS here — smoke tests and benches see 1 device; multi-device
tests spawn subprocesses (tests/distributed_impl.py sets the flag before
importing jax)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    # defensive re-registration: keeps `-m "not slow"` working even when
    # pytest is invoked from a cwd where pyproject.toml is not picked up
    config.addinivalue_line(
        "markers",
        "slow: heavy multi-device / subprocess tests, excluded from the "
        "fast tier (-m 'not slow')")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
