"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
1 device; multi-device tests spawn subprocesses or use their own module
(tests/test_tp_equivalence.py sets the flag before importing jax, so run it
in its own process: pytest handles this because it is imported first only
when collected — we guard with an env check)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
