"""Paged-KV serving tests (DESIGN.md §Paged KV).

Layers, bottom-up:

* BlockAllocator / PrefixCache — pure host units (no jax).
* PagedScheduler — admission on block availability, OOM deferral, chunk
  budgeting, copy-on-write ownership; driven host-only with fake tokens.
* paged_update / paged_view — device scatter/gather semantics.
* PagedServingEngine — the headline equivalences: paged engine tokens are
  bit-identical to the PR-1 ragged engine for ladder/standard/desync2 on a
  mixed staggered trace with a shared prompt prefix; prefix reuse matches a
  cold start while allocating strictly fewer fresh blocks; chunked prefill
  matches one-shot prefill.
"""

import jax.numpy as jnp
import numpy as np
import pytest

import jax
from repro.configs import REGISTRY, ResidualMode
from repro.models import transformer as tfm
from repro.serving.kv_cache import (
    BlockAllocator,
    PrefixCache,
    make_paged_kv_cache,
    paged_update,
    paged_view,
)
from repro.serving.scheduler import (
    ContinuousServingEngine,
    PagedScheduler,
    PagedServingEngine,
    Request,
    SamplingParams,
)


# ---------------------------------------------------------------------------
# allocator / prefix cache units (no jax)
# ---------------------------------------------------------------------------


def test_allocator_alloc_free_refcount_cycle():
    a = BlockAllocator(num_blocks=3, block_size=4)
    b0, b1 = a.alloc(), a.alloc()
    assert a.num_free() == 1 and a.num_in_use() == 2
    assert a.refcount(b0) == 1
    a.incref(b0)
    assert a.decref(b0) == 1  # still shared: not freeable yet
    assert a.decref(b0) == 0
    a.free(b0)
    assert a.num_free() == 2
    assert a.decref(b1) == 0
    a.free(b1)
    assert a.num_free() == 3 and a.total_allocs == 2


def test_allocator_oom_raises_and_guards_double_free():
    a = BlockAllocator(num_blocks=1, block_size=4)
    blk = a.alloc()
    with pytest.raises(RuntimeError):
        a.alloc()
    with pytest.raises(ValueError):
        a.free(blk)  # refcount still 1 (guards raise; tests/test_memory.py)


def test_prefix_cache_chain_lookup_and_lru_eviction():
    pc = PrefixCache()
    h0 = pc.chain(None, [1, 2, 3, 4])
    h1 = pc.chain(h0, [5, 6, 7, 8])
    assert h0 == pc.chain(None, [1, 2, 3, 4])  # deterministic
    assert h1 != pc.chain(None, [5, 6, 7, 8])  # chained, not per-block
    pc.insert(h0, 10)
    pc.insert(h1, 11)
    pc.insert(h1, 12)  # first writer wins
    assert pc.lookup(h1) == 11 and pc.contains_block(12) is False
    pc.mark_evictable(10)
    pc.mark_evictable(11)
    assert pc.num_evictable() == 2
    pc.revive(10)  # hit while evictable: pinned again
    assert pc.num_evictable() == 1
    assert pc.pop_lru() == 11  # registration dropped with the block
    assert pc.lookup(h1) is None and pc.lookup(h0) == 10


# ---------------------------------------------------------------------------
# scheduler host logic (no jax)
# ---------------------------------------------------------------------------


def _sched(n_slots=2, s_max=32, num_blocks=8, bs=4, prefix=True, **kw):
    alloc = BlockAllocator(num_blocks, bs)
    return PagedScheduler(
        n_slots,
        s_max,
        alloc,
        prefix_cache=PrefixCache() if prefix else None,
        **kw,
    )


def _drive_prefill(s, tok=7):
    """Run every pending chunk host-side; fake-sample `tok` on final ones."""
    retired = []
    for slot, chunk, start in s.prefill_work():
        seq = s.slots[slot]
        s.chunk_filled(slot, len(chunk))
        if start + len(chunk) == len(seq.request.prompt):
            if s.start_decode(slot, tok):
                retired.append(slot)
    return retired


def test_scheduler_admits_on_blocks_not_slots():
    # 2 slots but only 4 blocks of 4 tokens: the second 13-token prompt
    # (4 prompt blocks worst-case) must defer even though a slot is free.
    s = _sched(n_slots=2, s_max=32, num_blocks=4, bs=4, prefix=False)
    s.submit(Request(rid=0, prompt=list(range(13)), max_new_tokens=2))
    s.submit(Request(rid=1, prompt=list(range(13)), max_new_tokens=2))
    assert [r.rid for _, r in s.admissions()] == [0]
    assert s.deferred_admissions == 1 and len(s.queue) == 1
    # rid 0 runs to retirement; its blocks come back and rid 1 admits
    _drive_prefill(s)
    s.ensure_decode_blocks()
    assert s.observe(s.decoding_slots()[0], 9)  # max_new=2 -> length retire
    assert [r.rid for _, r in s.admissions()] == [1]


def test_scheduler_rejects_request_that_can_never_fit_the_pool():
    # worst case needs 4 blocks but the pool only has 3: submit must raise
    # instead of deferring at the queue head forever
    s = _sched(n_slots=1, s_max=32, num_blocks=3, bs=4, prefix=False)
    with pytest.raises(ValueError):
        s.submit(Request(rid=0, prompt=list(range(13)), max_new_tokens=2))
    s.submit(Request(rid=1, prompt=list(range(9)), max_new_tokens=2))  # fits


def test_scheduler_deferred_admission_leaves_lru_order_alone():
    s = _sched(n_slots=2, s_max=32, num_blocks=8, bs=4)
    old = list(range(100, 108))  # two full blocks
    s.submit(Request(rid=0, prompt=old + [1], max_new_tokens=1))
    s.admissions()
    _drive_prefill(s)  # retires; its full blocks become evictable
    assert s.prefix.num_evictable() == 2
    # rid 1 pins most of the pool and stays in flight
    s.submit(Request(rid=1, prompt=list(range(13)), max_new_tokens=8))
    s.admissions()
    _drive_prefill(s)
    # rid 2 hits the evictable prefix but its block budget does not fit:
    # the failed admission attempt must not promote those blocks in the LRU
    lru_before = list(s.prefix._evictable)
    s.submit(Request(rid=2, prompt=old + [2, 3, 4, 5], max_new_tokens=5))
    assert s.admissions() == []
    assert s.deferred_admissions == 1
    assert list(s.prefix._evictable) == lru_before
    assert all(s.allocator.refcount(b) == 0 for b in lru_before)


def test_scheduler_chunk_budget_bounds_per_step_prefill():
    s = _sched(num_blocks=16, bs=4, prefix=False, max_prefill_tokens=5)
    s.submit(Request(rid=0, prompt=list(range(12)), max_new_tokens=2))
    s.admissions()
    sizes = []
    while not s.slots[0].decoding:
        work = s.prefill_work()
        assert sum(len(c) for _, c, _ in work) <= 5
        sizes.append(len(work[0][1]))
        _drive_prefill(s)
    assert sizes == [5, 5, 2]


def test_scheduler_prefix_hit_shares_blocks_with_refcount():
    s = _sched(n_slots=2, s_max=32, num_blocks=8, bs=4)
    shared = list(range(100, 108))  # 2 full blocks
    s.submit(Request(rid=0, prompt=shared + [1, 2], max_new_tokens=2))
    s.admissions()
    _drive_prefill(s)  # registers the two full prompt blocks
    s.submit(Request(rid=1, prompt=shared + [3, 4, 5], max_new_tokens=2))
    s.admissions()
    seq0, seq1 = s.slots[0], s.slots[1]
    assert seq1.num_cached == 8 and seq1.blocks[:2] == seq0.blocks[:2]
    assert all(s.allocator.refcount(b) == 2 for b in seq1.blocks[:2])
    # rid 1's first chunk starts past the cached prefix (COW: shared full
    # blocks are never rewritten, divergence recomputes into fresh blocks)
    work = s.prefill_work()
    (slot, chunk, start) = [w for w in work if w[0] == 1][0]
    assert start == 8 and chunk == [3, 4, 5]
    assert s.stats()["prefix_hit_rate"] > 0


def test_scheduler_retired_prefix_blocks_stay_reusable_until_pressure():
    s = _sched(n_slots=1, s_max=32, num_blocks=4, bs=4)
    shared = list(range(8))
    s.submit(Request(rid=0, prompt=shared + [1], max_new_tokens=1))
    s.admissions()
    _drive_prefill(s)  # max_new=1: retires at first token
    assert s.slots[0] is None
    assert s.prefix.num_evictable() == 2  # cached, refcount 0, reclaimable
    s.submit(Request(rid=1, prompt=shared + [2], max_new_tokens=1))
    s.admissions()
    assert s.slots[0].num_cached == 8  # hit survives retirement


# ---------------------------------------------------------------------------
# paged pool device semantics
# ---------------------------------------------------------------------------


def test_paged_update_scatters_through_block_table_and_drops():
    cache = make_paged_kv_cache(num_blocks=4, block_size=2, hkv=1, hd=4,
                                dtype=jnp.float32)
    bt = jnp.asarray([[3, 1], [2, 0]], jnp.int32)  # 2 rows, 2 blocks each
    kv = jnp.stack([jnp.full((1, 1, 4), 5.0), jnp.full((1, 1, 4), 9.0)])
    pos = jnp.asarray([[3], [-1]], jnp.int32)  # row 0 at pos 3, row 1 idle
    cache = paged_update(cache, kv, kv, pos, bt)
    pool = np.asarray(cache.k[0])  # (N_tok, hd)
    assert pool[1 * 2 + 1, 0] == 5.0  # block 1, offset 1
    assert np.abs(pool).sum() == pytest.approx(4 * 5.0)  # row 1 dropped
    view = paged_view(cache, bt)
    assert view.k.shape == (2, 1, 4, 4)
    assert float(view.k[0, 0, 3, 0]) == 5.0  # logical position 3
    assert np.array_equal(np.asarray(view.slot_pos[0]), [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# engine equivalences (the acceptance invariants)
# ---------------------------------------------------------------------------


def _tiny_cfg(mode):
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=256
    )
    return cfg.replace(residual_mode=ResidualMode(mode))


def _params(cfg):
    return tfm.init_params(cfg, jax.random.key(0))


def _mixed_trace(vocab, rng):
    """Variable prompt lengths, one shared system prefix, mixed sampling."""
    shared = rng.integers(0, vocab, 16).tolist()  # 2 full blocks at bs=8
    cases = [
        (shared + rng.integers(0, vocab, 5).tolist(), 6, SamplingParams()),
        (
            shared + rng.integers(0, vocab, 9).tolist(),
            4,
            SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=7),
        ),
        (
            rng.integers(0, vocab, 7).tolist(),  # no shared prefix
            5,
            SamplingParams(temperature=1.2, seed=3),
        ),
        (shared + rng.integers(0, vocab, 3).tolist(), 5, SamplingParams()),
    ]
    return [
        Request(rid=i, prompt=p, max_new_tokens=g, sampling=sp)
        for i, (p, g, sp) in enumerate(cases)
    ]


def _clone(r):
    return Request(
        rid=r.rid,
        prompt=list(r.prompt),
        max_new_tokens=r.max_new_tokens,
        sampling=r.sampling,
    )


def _serve_staggered(engine, reqs):
    engine.submit(_clone(reqs[0]))
    engine.submit(_clone(reqs[1]))
    engine.step()
    for r in reqs[2:]:
        engine.submit(_clone(r))
    return engine.run()


@pytest.mark.parametrize("mode", ["ladder", "standard", "desync2"])
def test_paged_engine_matches_ragged_engine(mode):
    """Mixed trace (variable prompts, staggered arrivals, shared prefix):
    the paged engine must emit token sequences bit-identical to the PR-1
    ragged path, while prefix sharing measurably reduces fresh prefill."""
    cfg = _tiny_cfg(mode)
    params = _params(cfg)
    reqs = _mixed_trace(cfg.vocab_size, np.random.default_rng(0))

    ragged = ContinuousServingEngine(cfg, params, batch_slots=2, s_max=48)
    want = _serve_staggered(ragged, reqs)

    # block_size divides s_max so the gathered view width equals the ragged
    # slot count; budget 16 forces the longer prompts to prefill chunked
    paged = PagedServingEngine(
        cfg,
        params,
        batch_slots=2,
        s_max=48,
        block_size=8,
        max_prefill_tokens=16,
    )
    got = _serve_staggered(paged, reqs)

    assert set(got) == set(want)
    for rid in want:
        assert got[rid].tokens == want[rid].tokens, rid
    assert paged.scheduler.prefix_hit_tokens > 0  # sharing actually engaged


def test_prefix_reuse_matches_cold_start_with_fewer_fresh_blocks():
    cfg = _tiny_cfg("ladder")
    params = _params(cfg)
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab_size, 24).tolist()  # 3 full blocks
    tail = rng.integers(0, cfg.vocab_size, 6).tolist()
    mk = lambda rid, t: Request(
        rid=rid, prompt=shared + t, max_new_tokens=5, sampling=SamplingParams()
    )

    cold = PagedServingEngine(cfg, params, batch_slots=2, s_max=64,
                              block_size=8)
    cold.submit(mk(1, tail))
    want = cold.run()[1].tokens

    warm = PagedServingEngine(cfg, params, batch_slots=2, s_max=64,
                              block_size=8)
    warm.submit(mk(0, rng.integers(0, cfg.vocab_size, 4).tolist()))
    warm.run()
    warm.submit(mk(1, tail))
    assert warm.run()[1].tokens == want  # bit-identical to cold start
    st = warm.scheduler.request_stats
    assert st[1]["cached_tokens"] == 24
    assert st[1]["fresh_blocks"] < st[0]["fresh_blocks"]  # strictly fewer


def test_chunked_prefill_matches_one_shot():
    cfg = _tiny_cfg("ladder")
    params = _params(cfg)
    rng = np.random.default_rng(2)
    req = Request(
        rid=0,
        prompt=rng.integers(0, cfg.vocab_size, 33).tolist(),
        max_new_tokens=6,
        sampling=SamplingParams(temperature=0.9, top_k=30, seed=5),
    )
    outs = []
    for budget in (7, 64):  # 5 chunks vs one shot
        e = PagedServingEngine(
            cfg,
            params,
            batch_slots=1,
            s_max=48,
            block_size=8,
            max_prefill_tokens=budget,
            prefix_caching=False,
        )
        e.submit(_clone(req))
        outs.append(e.run()[0].tokens)
    assert outs[0] == outs[1]


def test_paged_engine_oom_defers_admission_but_completes():
    cfg = _tiny_cfg("ladder")
    params = _params(cfg)
    rng = np.random.default_rng(3)
    eng = PagedServingEngine(
        cfg,
        params,
        batch_slots=2,
        s_max=48,
        block_size=8,
        num_blocks=5,  # too small for two in-flight requests
        prefix_caching=False,
    )
    for rid in range(2):
        eng.submit(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, 20).tolist(),
                max_new_tokens=4,
                sampling=SamplingParams(),
            )
        )
    fin = eng.run()
    assert sorted(fin) == [0, 1]  # both served, serially
    assert eng.scheduler.deferred_admissions > 0


def test_paged_engine_rejects_unsupported_configs():
    cfg = REGISTRY["rwkv6-7b"].reduced(n_layers=2)
    with pytest.raises(NotImplementedError):
        PagedServingEngine(cfg, params=None, batch_slots=1, s_max=16)
    from repro.configs import ParallelConfig

    cfg2 = _tiny_cfg("ladder")
    with pytest.raises(NotImplementedError):
        PagedServingEngine(
            cfg2,
            params=None,
            batch_slots=2,
            s_max=16,
            pcfg=ParallelConfig(tp=1, dp=2),
        )
