"""Hypothesis property tests on the system's invariants.

hypothesis is an OPTIONAL dev dependency (requirements-dev.txt): when it is
absent this module must SKIP, not error the whole collection — tier-1 runs
on the bare runtime image."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ResidualMode
from repro.core import residual as topo
from repro.models.layers import sharded_cross_entropy
from repro.parallel.collectives import NULL_ENV
from repro.parallel.sharding import tp_head_plan
from repro.training.data import SyntheticLM
from repro.launch import roofline as rl

SETTINGS = settings(max_examples=25, deadline=None)


@SETTINGS
@given(n_sub=st.integers(1, 8), d=st.integers(2, 16),
       seed=st.integers(0, 100))
def test_ladder_finalize_totals_all_subblocks(n_sub, d, seed):
    """Invariant: after finalize, the ladder residual equals
    x0 + sum_i psum(h_i(input_i)) — every sub-block contributes exactly
    once regardless of stack depth (pendings never drop)."""
    rng = np.random.default_rng(seed)
    x0 = jnp.asarray(rng.normal(size=(1, 2, d)), jnp.float32)
    outs = [jnp.asarray(rng.normal(size=(1, 2, d)), jnp.float32)
            for _ in range(n_sub)]
    fns = [lambda p, x, s, o=o: (o, s, jnp.zeros((), jnp.float32))
           for o in outs]  # constant sub-blocks: input-independent
    carry = topo.init_carry(ResidualMode.LADDER, x0)
    for i, fn in enumerate(fns):
        carry, _ = topo.subblock_step(ResidualMode.LADDER, fn, None, carry,
                                      None, NULL_ENV, i)
    got, _ = topo.finalize_carry(ResidualMode.LADDER, carry, NULL_ENV)
    want = x0 + sum(outs)
    np.testing.assert_allclose(got, want, atol=1e-5)


@SETTINGS
@given(n_sub=st.integers(1, 9), desync_n=st.sampled_from([2, 4]),
       seed=st.integers(0, 50))
def test_desync_totals_all_subblocks(n_sub, desync_n, seed):
    """Same conservation invariant for desync (at TP=1 psum==identity)."""
    mode = (ResidualMode.DESYNC2 if desync_n == 2 else ResidualMode.DESYNC4)
    rng = np.random.default_rng(seed)
    x0 = jnp.asarray(rng.normal(size=(1, 2, 4)), jnp.float32)
    outs = [jnp.asarray(rng.normal(size=(1, 2, 4)), jnp.float32)
            for _ in range(n_sub)]
    fns = [lambda p, x, s, o=o: (o, s, jnp.zeros((), jnp.float32))
           for o in outs]
    carry = topo.init_carry(mode, x0)
    for i, fn in enumerate(fns):
        carry, _ = topo.subblock_step(mode, fn, None, carry, None,
                                      NULL_ENV, i, desync_n)
    got, _ = topo.finalize_carry(mode, carry, NULL_ENV)
    np.testing.assert_allclose(got, x0 + sum(outs), atol=1e-5)


@SETTINGS
@given(v=st.integers(8, 300), b=st.integers(1, 4), s=st.integers(1, 8),
       seed=st.integers(0, 20))
def test_sharded_xent_matches_dense(v, b, s, seed):
    """Vocab-sharded cross entropy == plain log_softmax gather, with
    padded columns masked."""
    rng = np.random.default_rng(seed)
    pad_v = v + (-v) % 16
    logits = jnp.asarray(rng.normal(size=(b, s, pad_v)) * 3, jnp.float32)
    targets = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    nll = sharded_cross_entropy(logits, targets, NULL_ENV, true_vocab=v)
    lse = jax.nn.log_softmax(
        jnp.where(jnp.arange(pad_v) < v, logits, -1e30), axis=-1)
    want = -jnp.take_along_axis(lse, targets[..., None], -1)[..., 0]
    np.testing.assert_allclose(nll, want, atol=1e-4, rtol=1e-4)


@SETTINGS
@given(h=st.sampled_from([8, 12, 16, 24, 32, 48, 64]),
       kv_div=st.sampled_from([1, 2, 4, 8]),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
def test_head_plan_invariants(h, kv_div, tp):
    """The TP head plan always yields divisible effective counts, maps every
    original q head exactly once, and never maps a bogus head."""
    kv = max(1, h // kv_div)
    if h % kv:
        return
    try:
        plan = tp_head_plan(h, kv, tp)
    except ValueError:
        # only the documented unsupported layouts may raise: GQA where the
        # kv count neither divides nor is divided by tp (and not MHA)
        assert kv % tp != 0 and not (kv < tp and tp % kv == 0) and h != kv
        return
    assert plan.h_eff % tp == 0
    assert plan.kv_eff % tp == 0
    real_q = [q for q in plan.q_map if q >= 0]
    assert sorted(real_q) == list(range(h))          # exactly once each
    assert all(0 <= k_ < kv for k_ in plan.kv_map if k_ >= 0)
    # group structure: each eff q slot's kv head serves it
    g_eff = plan.h_eff // plan.kv_eff
    for qi, q in enumerate(plan.q_map):
        if q < 0:
            continue
        kv_slot = qi // g_eff
        assert plan.kv_map[kv_slot] == q // (h // kv)


@SETTINGS
@given(step=st.integers(0, 1000), seed=st.integers(0, 10))
def test_synthetic_data_pure_function_of_step(step, seed):
    ld = SyntheticLM(vocab_size=64, seq_len=8, global_batch=2, seed=seed)
    a = ld.batch_at(step)
    b = ld.batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 64


@SETTINGS
@given(n=st.integers(1, 64), kind=st.sampled_from(
    ["all-reduce", "all-gather", "reduce-scatter", "collective-permute"]))
def test_ring_weights_bounded(n, kind):
    w = rl._ring_weight(kind, n)
    assert 0 <= w <= 2
    if n == 1 and kind != "collective-permute":
        assert w == 0.0


@SETTINGS
@given(seed=st.integers(0, 1000), n=st.integers(1, 2000),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_int8_roundtrip_error_bound(seed, n, scale):
    """quantize_int8 -> dequantize_int8 error is bounded per element by
    half a quantization step: max|block| / 254 (round-to-nearest of a
    symmetric 127-level grid), for any shape and magnitude."""
    from repro.quant import dequantize_int8, quantize_int8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    img = dequantize_int8(q, s, x.shape)
    # per-256-block bound: each element's error <= its block's scale / 2
    bound = np.repeat(np.asarray(s) / 2, 256)[:n] + 1e-7
    assert np.all(np.abs(np.asarray(img - x)) <= bound)


@SETTINGS
@given(seed=st.integers(0, 1000), tokens=st.integers(1, 32),
       hd=st.sampled_from([4, 16, 64]), scale=st.sampled_from([1e-2, 1.0, 50.0]))
def test_kv_int8_roundtrip_error_bound(seed, tokens, hd, scale):
    """Per-(token, head) KV quantization round-trips within half a step of
    each token's own scale — the bound that makes the int8 pool's logit
    error controllable (DESIGN.md §KV memory tiers)."""
    from repro.quant import dequantize_kv, quantize_kv
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(tokens, 2, hd)) * scale, jnp.float32)
    q, s = quantize_kv(x)
    img = dequantize_kv(q, s)
    bound = np.asarray(s)[..., None] / 2 + 1e-7
    assert np.all(np.abs(np.asarray(img - x)) <= bound)
    # idempotence: re-quantizing the image is a fixed point (the swap
    # tier's "bytes move, never re-quantized" contract is safe even if a
    # bug re-quantized — but we pin exactness anyway)
    q2, s2 = quantize_kv(img)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))


@SETTINGS
@given(seed=st.integers(0, 200))
def test_int8_paged_attention_logit_error_bounded(seed):
    """int8-vs-fp paged attention: the pre-softmax logits (scores) shift by
    at most scale * (|q| . k_err + |k| . q-side rounding) and the output by
    a comparable margin — asserted against an analytic per-case bound, not
    a magic constant."""
    from repro.parallel.collectives import NULL_ENV
    from repro.models.attention import _cached_attention
    from repro.serving.kv_cache import (make_paged_kv_cache, paged_update,
                                        paged_view)
    bs, hkv, hd, nb, m = 4, 2, 16, 8, 3
    rng = np.random.default_rng(seed)
    kv_len = int(rng.integers(1, m * bs))
    kn = jnp.asarray(rng.normal(size=(1, kv_len, hkv, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(1, kv_len, hkv, hd)), jnp.float32)
    bt = jnp.asarray(rng.choice(nb, size=m, replace=False)[None], jnp.int32)
    pos = jnp.arange(kv_len)[None]
    q = jnp.asarray(rng.normal(size=(1, 1, hkv * 2, hd)), jnp.float32)
    qpos = jnp.asarray([[kv_len - 1]], jnp.int32)
    outs = {}
    for quant in ("fp", "int8"):
        c = make_paged_kv_cache(nb, bs, hkv, hd, jnp.float32, quant=quant)
        c = paged_update(c, kn, vn, pos, bt)
        outs[quant] = np.asarray(_cached_attention(
            q * hd ** -0.5, paged_view(c, bt), qpos, NULL_ENV, softcap=0.0))
    # v error: each element within v_scale/2 of fp; attention output is a
    # convex combination of v rows, so |out_int8 - out_fp| is bounded by
    # max-token v error plus the k-side softmax reweighting effect —
    # coarsely, a few quantization steps of the largest row
    v_step = float(np.abs(np.asarray(vn)).max()) / 254
    k_step = float(np.abs(np.asarray(kn)).max()) / 254
    qmag = float(np.abs(np.asarray(q)).max()) * hd ** -0.5
    vmax = float(np.abs(np.asarray(vn)).max())
    # score perturbation |ds| <= qmag * k_step * hd; softmax Lipschitz in
    # infinity norm amplifies by <= 2 * |ds| on the weights, weights hit v
    bound = v_step + 2 * (qmag * k_step * hd) * vmax + 1e-6
    assert np.abs(outs["int8"] - outs["fp"]).max() <= bound


@SETTINGS
@given(seed=st.integers(0, 30), rows=st.integers(1, 6),
       d=st.sampled_from([8, 16, 64]))
def test_rmsnorm_kernel_property(seed, rows, d):
    """Kernel == oracle on arbitrary shapes (scale/shift invariances are
    captured by comparing against the direct formula)."""
    from repro.kernels.rmsnorm import rmsnorm
    from repro.kernels.ref import rmsnorm_ref
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, d)) * 5, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d,)) * 0.1, jnp.float32)
    got = rmsnorm(x, w, interpret=True)
    np.testing.assert_allclose(got, rmsnorm_ref(x, w), atol=1e-5, rtol=1e-5)


@SETTINGS
@given(tp=st.sampled_from([2, 4]), chunks=st.integers(1, 6),
       n=st.integers(1, 700), seed=st.integers(0, 100))
def test_compressed_all_reduce_error_bound(tp, chunks, n, seed):
    """Compressed ring vs fp32 sum: per-element error <= sum_j scale_j / 2
    (each source shard contributes at most half a quantization step), for
    arbitrary tp x chunk x ragged-length combinations."""
    from repro.parallel.overlap import (chunk_bounds,
                                        simulate_compressed_all_reduce)
    from repro.quant import BLOCK, quantize_int8
    rng = np.random.default_rng(seed)
    shards = jnp.asarray(rng.normal(size=(tp, n)) *
                         rng.uniform(0.1, 10), jnp.float32)
    out = simulate_compressed_all_reduce(shards, chunks=chunks)
    want = np.asarray(jnp.sum(shards, axis=0))
    bound = np.zeros(n, np.float64)
    for start, size in chunk_bounds(n, chunks):
        for j in range(tp):
            _, scale = quantize_int8(shards[j, start:start + size])
            bound[start:start + size] += \
                0.5 * np.asarray(jnp.repeat(scale, BLOCK)[:size])
    err = np.abs(np.asarray(out[0]) - want)
    assert np.all(err <= bound + 1e-6)


@SETTINGS
@given(tp=st.sampled_from([2, 4]), seed=st.integers(0, 100))
def test_compressed_all_reduce_scale_idempotence(tp, seed):
    """Re-quantizing a dequantized image is a fixed point (mirrors the KV
    swap-tier contract) PROVIDED the blocking aligns: running values that
    are already exact int8 multiples through a compressed ring whose chunk
    boundaries fall on quant-block boundaries introduces NO extra error
    beyond the summation itself.  (Misaligned chunks re-block and change
    scales — that case is covered by the general error bound above.)"""
    from repro.parallel.overlap import simulate_compressed_all_reduce
    from repro.quant import BLOCK, dequantize_int8, quantize_int8
    rng = np.random.default_rng(seed)
    n = 2 * BLOCK  # chunks=2 -> each ring chunk is exactly one quant block
    raw = jnp.asarray(rng.normal(size=(tp, n)), jnp.float32)
    imgs = []
    for j in range(tp):
        q, s = quantize_int8(raw[j])
        img = dequantize_int8(q, s, (n,))
        q2, s2 = quantize_int8(img)  # fixed point: same codes
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
        imgs.append(img)
    shards = jnp.stack(imgs)
    out = simulate_compressed_all_reduce(shards, chunks=2)
    # every shard's image survives the wire exactly -> the reduce equals
    # the plain fp sum of the images, bit-for-bit at tp=2 and within
    # association-rounding above
    want = np.asarray(jnp.sum(shards, axis=0))
    np.testing.assert_allclose(np.asarray(out[0]), want, rtol=1e-6,
                               atol=1e-6)


@SETTINGS
@given(tp=st.sampled_from([2, 4]), chunks=st.integers(1, 4),
       seed=st.integers(0, 50))
def test_compressed_all_reduce_overflow_safe(tp, chunks, seed):
    """Worst-case magnitudes (+-1e30 activations, all-zero chunks) must
    stay finite: scales absorb the magnitude, zero blocks quantize to
    exact zero (scale 0 guarded by _EPS), nothing overflows int8 or f32."""
    from repro.parallel.overlap import simulate_compressed_all_reduce
    rng = np.random.default_rng(seed)
    big = rng.choice([-1e30, 1e30], size=(tp, 256)).astype(np.float32)
    zeros = np.zeros((tp, 256), np.float32)
    mixed = np.concatenate([big, zeros, rng.normal(size=(tp, 64))
                            .astype(np.float32) * 1e-20], axis=1)
    out = np.asarray(simulate_compressed_all_reduce(
        jnp.asarray(mixed), chunks=chunks))
    assert np.all(np.isfinite(out))
    for i in range(1, tp):
        np.testing.assert_array_equal(out[0], out[i])
    # zero chunks come back exactly zero
    np.testing.assert_array_equal(out[0, 256:512], np.zeros(256, np.float32))
