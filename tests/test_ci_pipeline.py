"""CI pipeline sanity: the GitHub Actions workflow stays structurally valid
(jobs, triggers, jax matrix, gate commands) and the serve-bench regression
gate accepts the committed baseline while rejecting a degraded run."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
WORKFLOW = ROOT / ".github" / "workflows" / "ci.yml"
BASELINE = ROOT / "results" / "serve_bench.json"
CHECK = ROOT / "scripts" / "check_bench.py"


def _steps_text(job):
    return " ".join(
        str(s.get("run", "")) + str(s.get("uses", "")) for s in job["steps"]
    )


@pytest.fixture(scope="module")
def workflow():
    yaml = pytest.importorskip("yaml")
    data = yaml.safe_load(WORKFLOW.read_text())
    # YAML 1.1 parses the bare `on:` key as boolean True
    data["on"] = data.get("on", data.get(True))
    return data


def test_workflow_triggers(workflow):
    on = workflow["on"]
    assert "push" in on and "pull_request" in on
    assert "schedule" in on and on["schedule"][0]["cron"]


def test_workflow_fast_tier_runs_ci_sh_on_jax_matrix(workflow):
    fast = workflow["jobs"]["fast"]
    assert "scripts/ci.sh" in _steps_text(fast)
    versions = [m["jax-version"] for m in fast["strategy"]["matrix"]["include"]]
    assert len(versions) == 2
    assert any(str(v).startswith("0.4") for v in versions)  # compat shims
    assert "latest" in versions
    # pip caching on every setup-python step
    for job in workflow["jobs"].values():
        setups = [s for s in job["steps"] if "setup-python" in str(s.get("uses"))]
        assert setups and all(s["with"]["cache"] == "pip" for s in setups)


def test_workflow_lint_and_nightly_jobs(workflow):
    assert "ruff" in (ROOT / "requirements-dev.txt").read_text()
    assert "--lint" in _steps_text(workflow["jobs"]["lint"])
    nightly = _steps_text(workflow["jobs"]["nightly"])
    assert "--full" in nightly and "check_bench.py" in nightly


def test_gitignore_covers_scratch_dirs():
    text = (ROOT / ".gitignore").read_text()
    for pat in (".pytest_cache/", "__pycache__/", "*.egg-info/",
                "results/*.tmp.json"):
        assert pat in text, pat


def test_check_bench_accepts_committed_baseline():
    r = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(BASELINE)],
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_check_bench_rejects_degraded_and_missing_rows(tmp_path):
    base = json.loads(BASELINE.read_text())
    degraded = json.loads(json.dumps(base))
    for row in degraded["rows"]:
        row["tokens_per_s"] *= 0.1
    bad = tmp_path / "degraded.json"
    bad.write_text(json.dumps(degraded))
    r = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(bad)],
        capture_output=True,
        text=True,
    )
    assert r.returncode != 0 and "FAIL" in r.stdout

    dropped = json.loads(json.dumps(base))
    dropped["rows"] = dropped["rows"][1:]
    bad2 = tmp_path / "dropped.json"
    bad2.write_text(json.dumps(dropped))
    r2 = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(bad2)],
        capture_output=True,
        text=True,
    )
    assert r2.returncode != 0 and "missing" in r2.stdout


def test_check_bench_p99_gate(tmp_path):
    base = json.loads(BASELINE.read_text())
    slow = json.loads(json.dumps(base))
    for row in slow["rows"]:
        p99 = row["per_token_latency_ms"]["p99"]
        row["per_token_latency_ms"]["p99"] = p99 * 10
    bad = tmp_path / "slow.json"
    bad.write_text(json.dumps(slow))
    r = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(bad)],
        capture_output=True,
        text=True,
    )
    assert r.returncode != 0
