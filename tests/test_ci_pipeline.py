"""CI pipeline sanity: the GitHub Actions workflow stays structurally valid
(jobs, triggers, jax matrix, gate commands), the serve-bench regression
gate accepts the committed baseline while rejecting a degraded run, and
the docs smoke-runner (scripts/check_docs.py) extracts/executes/fails the
right blocks."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
WORKFLOW = ROOT / ".github" / "workflows" / "ci.yml"
BASELINE = ROOT / "results" / "serve_bench.json"
CHECK = ROOT / "scripts" / "check_bench.py"
CHECK_DOCS = ROOT / "scripts" / "check_docs.py"


def _steps_text(job):
    return " ".join(
        str(s.get("run", "")) + str(s.get("uses", "")) for s in job["steps"]
    )


@pytest.fixture(scope="module")
def workflow():
    yaml = pytest.importorskip("yaml")
    data = yaml.safe_load(WORKFLOW.read_text())
    # YAML 1.1 parses the bare `on:` key as boolean True
    data["on"] = data.get("on", data.get(True))
    return data


def test_workflow_triggers(workflow):
    on = workflow["on"]
    assert "push" in on and "pull_request" in on
    assert "schedule" in on and on["schedule"][0]["cron"]


def test_workflow_fast_tier_runs_ci_sh_on_jax_matrix(workflow):
    fast = workflow["jobs"]["fast"]
    assert "scripts/ci.sh" in _steps_text(fast)
    versions = [m["jax-version"] for m in fast["strategy"]["matrix"]["include"]]
    assert len(versions) == 2
    assert any(str(v).startswith("0.4") for v in versions)  # compat shims
    assert "latest" in versions
    # pip caching on every setup-python step
    for job in workflow["jobs"].values():
        setups = [s for s in job["steps"] if "setup-python" in str(s.get("uses"))]
        assert setups and all(s["with"]["cache"] == "pip" for s in setups)


def test_workflow_lint_and_nightly_jobs(workflow):
    assert "ruff" in (ROOT / "requirements-dev.txt").read_text()
    assert "--lint" in _steps_text(workflow["jobs"]["lint"])
    nightly = _steps_text(workflow["jobs"]["nightly"])
    assert "--full" in nightly and "check_bench.py" in nightly


def test_workflow_docs_job_runs_docs_gate(workflow):
    assert "--docs" in _steps_text(workflow["jobs"]["docs"])
    assert "--docs" in (ROOT / "scripts" / "ci.sh").read_text()


# ---------------------------------------------------------------------------
# docs smoke-runner (scripts/check_docs.py)
# ---------------------------------------------------------------------------


def _run_docs(*args):
    return subprocess.run(
        [sys.executable, str(CHECK_DOCS), *args],
        capture_output=True,
        text=True,
    )


def test_check_docs_extracts_and_runs_bash_blocks(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text(
        "# t\n\n```bash\necho hello-docs\n```\n\n"
        "```python\nraise SystemExit(1)  # not bash: must not run\n```\n\n"
        "```bash\n# docs: skip (expensive)\nexit 1\n```\n"
    )
    r = _run_docs(str(md))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok" in r.stdout and "skip" in r.stdout
    assert "2 block(s), 1 run, 0 failure(s)" in r.stdout


def test_check_docs_fails_on_broken_block_and_empty_docs(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("```bash\nfalse\n```\n")
    r = _run_docs(str(bad))
    assert r.returncode != 0 and "FAIL" in r.stdout


def test_check_docs_timeout_is_a_failure_not_a_crash(tmp_path):
    md = tmp_path / "hang.md"
    md.write_text("```bash\nsleep 30\n```\n\n```bash\necho after\n```\n")
    r = _run_docs(str(md), "--timeout", "1")
    assert r.returncode != 0
    assert "timed out" in r.stdout
    assert "after" in r.stdout  # later blocks still run and report

    empty = tmp_path / "empty.md"
    empty.write_text("# no code here\n")
    assert _run_docs(str(empty)).returncode != 0
    # every block skipped == nothing guards the quickstart
    allskip = tmp_path / "allskip.md"
    allskip.write_text("```bash\n# docs: skip\necho hi\n```\n")
    assert _run_docs(str(allskip)).returncode != 0


def test_check_docs_readme_blocks_are_listed():
    """The README keeps executable quickstart blocks (the docs CI job runs
    them for real; here we only check extraction finds runnable ones)."""
    r = _run_docs("--list")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "README.md" in r.stdout and "docs/serving.md" in r.stdout
    assert "run   " in r.stdout


def test_gitignore_covers_scratch_dirs():
    text = (ROOT / ".gitignore").read_text()
    for pat in (".pytest_cache/", "__pycache__/", "*.egg-info/",
                "results/*.tmp.json"):
        assert pat in text, pat


def test_check_bench_accepts_committed_baseline():
    r = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(BASELINE)],
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_check_bench_rejects_degraded_and_missing_rows(tmp_path):
    base = json.loads(BASELINE.read_text())
    degraded = json.loads(json.dumps(base))
    for row in degraded["rows"]:
        row["tokens_per_s"] *= 0.1
    bad = tmp_path / "degraded.json"
    bad.write_text(json.dumps(degraded))
    r = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(bad)],
        capture_output=True,
        text=True,
    )
    assert r.returncode != 0 and "FAIL" in r.stdout

    dropped = json.loads(json.dumps(base))
    dropped["rows"] = dropped["rows"][1:]
    bad2 = tmp_path / "dropped.json"
    bad2.write_text(json.dumps(dropped))
    r2 = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(bad2)],
        capture_output=True,
        text=True,
    )
    assert r2.returncode != 0 and "missing" in r2.stdout


def test_check_bench_kernel_bytes_gate(tmp_path):
    """The paged-attention kernel's bytes-read model is gated: kernel
    traffic above the gather path's (or a missing artifact) must fail."""
    kb_path = ROOT / "results" / "kernel_bench.json"
    bad = json.loads(kb_path.read_text())
    for row in bad["rows"]:
        row["bytes_kernel"] = row["bytes_gather_full"] * 2
        row["reduction_vs_full"] = 0.5
    p = tmp_path / "kernel_bad.json"
    p.write_text(json.dumps(bad))
    r = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(BASELINE),
         "--kernel-bench", str(p)],
        capture_output=True,
        text=True,
    )
    assert r.returncode != 0 and "kernel_bench" in r.stdout

    r2 = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(BASELINE),
         "--kernel-bench", str(tmp_path / "nope.json")],
        capture_output=True,
        text=True,
    )
    assert r2.returncode != 0 and "missing" in r2.stdout


def test_check_bench_p99_gate(tmp_path):
    base = json.loads(BASELINE.read_text())
    slow = json.loads(json.dumps(base))
    for row in slow["rows"]:
        p99 = row["per_token_latency_ms"]["p99"]
        row["per_token_latency_ms"]["p99"] = p99 * 10
    bad = tmp_path / "slow.json"
    bad.write_text(json.dumps(slow))
    r = subprocess.run(
        [sys.executable, str(CHECK), "--candidate", str(bad)],
        capture_output=True,
        text=True,
    )
    assert r.returncode != 0
