"""Multi-device checks that need fake devices BEFORE jax initialises.
Run as a subprocess by tests/test_distributed.py:
    python tests/distributed_impl.py <check-name>
Prints PASS/FAIL lines; exit code 0 iff all pass.
"""

import os
import sys

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=4"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY, ParallelConfig, ResidualMode, TrainConfig
from repro.parallel import compat
from repro.models import transformer as tfm
from repro.parallel import sharding, tp as tpmod
from repro.parallel.collectives import AxisEnv, NULL_ENV
from repro.training import optimizer as opt

MESH = compat.make_mesh((2, 2), ("data", "model"))
OK = True


def check(name, cond):
    global OK
    print(f"{'PASS' if cond else 'FAIL'} {name}")
    OK = OK and bool(cond)


def _cfg(arch, mode="ladder", **kw):
    cfg = REGISTRY[arch].reduced(n_layers=4, **kw)
    cfg = cfg.replace(residual_mode=ResidualMode(mode))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0, aux_loss_weight=0.0))
    return cfg


def _batch(cfg, b=4, s=16):
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    batch = dict(tokens=tokens, targets=jnp.roll(tokens, -1, axis=1))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (b, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (b, s * cfg.encoder_seq_ratio,
                                cfg.d_model)) * 0.02
    return batch


def tp_equivalence():
    """shard_map TP (tp=2, dp=2) == single-device, all families/topologies."""
    pcfg = ParallelConfig(tp=2, dp=2)
    tcfg = TrainConfig(grad_clip=1e9, warmup_steps=1, total_steps=10)
    cases = [("stablelm-3b", m) for m in
             ["standard", "ladder", "parallel"]] + \
        [(a, "ladder") for a in
         ["gemma3-4b", "deepseek-v2-lite-16b", "dbrx-132b", "zamba2-2.7b",
          "rwkv6-7b", "whisper-small", "llava-next-mistral-7b"]]
    for arch, mode in cases:
        cfg = _cfg(arch, mode)
        params = tfm.init_params(cfg, jax.random.key(0))
        params, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
        batch = _batch(cfg)
        loss_ref, _ = tpmod.lm_loss(cfg, params, batch, NULL_ENV, tcfg, True)
        step_fn, in_specs, _ = tpmod.build_train_step(cfg, MESH, pcfg, tcfg)
        state = opt.adamw_init(params)
        with compat.set_mesh(MESH):
            _, _, m = jax.jit(step_fn)(params, state, batch,
                                       jnp.zeros((), jnp.int32))
        dl = abs(float(m["loss"]) - float(loss_ref))
        check(f"tp_equiv {arch}/{mode} dloss={dl:.2e}", dl < 1e-4)


def fsdp_equivalence():
    pcfg = ParallelConfig(tp=2, dp=2)
    tcfg = TrainConfig(grad_clip=1e9, warmup_steps=1, total_steps=10)
    for arch in ["stablelm-3b", "dbrx-132b", "zamba2-2.7b"]:
        cfg = _cfg(arch, "ladder").replace(remat="block")
        batch = _batch(cfg)
        p0, s0, _ = tpmod.init_train_state(cfg, pcfg, jax.random.key(0))
        f0, *_ = tpmod.build_train_step(cfg, MESH, pcfg, tcfg)
        p1, s1, _ = tpmod.init_train_state(cfg, pcfg, jax.random.key(0),
                                           fsdp=True)
        f1, *_ = tpmod.build_train_step(cfg, MESH, pcfg, tcfg, fsdp=True)
        with compat.set_mesh(MESH):
            a = jax.jit(f0)(p0, s0, batch, jnp.zeros((), jnp.int32))
            b = jax.jit(f1)(p1, s1, batch, jnp.zeros((), jnp.int32))
        dl = abs(float(a[2]["loss"]) - float(b[2]["loss"]))
        dg = abs(float(a[2]["grad_norm"]) - float(b[2]["grad_norm"]))
        de = float(jnp.max(jnp.abs(a[0]["embed"] - b[0]["embed"])))
        check(f"fsdp_equiv {arch} dloss={dl:.1e} dgn={dg:.1e} de={de:.1e}",
              dl < 1e-5 and dg < 1e-3 and de < 1e-6)


def zero1_equivalence():
    pcfg = ParallelConfig(tp=2, dp=2)
    tcfg = TrainConfig(grad_clip=1e9, warmup_steps=1, total_steps=10)
    cfg = _cfg("stablelm-3b", "standard")
    batch = _batch(cfg)
    p0, s0, _ = tpmod.init_train_state(cfg, pcfg, jax.random.key(0))
    f0, *_ = tpmod.build_train_step(cfg, MESH, pcfg, tcfg)
    p1, s1, _ = tpmod.init_train_state(cfg, pcfg, jax.random.key(0),
                                       zero1=True)
    f1, in1, _ = tpmod.build_train_step(cfg, MESH, pcfg, tcfg, zero1=True)
    env = tpmod.make_axis_env(pcfg)
    seed = compat.shard_map(lambda p, s: opt.zero1_seed_master(p, s, env),
                            MESH, (in1[0], in1[1]), in1[1])
    with compat.set_mesh(MESH):
        s1 = jax.jit(seed)(p1, s1)
        a = jax.jit(f0)(p0, s0, batch, jnp.zeros((), jnp.int32))
        b = jax.jit(f1)(p1, s1, batch, jnp.zeros((), jnp.int32))
    dp_ = max(float(jnp.max(jnp.abs(x - y)))
              for x, y in zip(jax.tree.leaves(a[0]), jax.tree.leaves(b[0])))
    check(f"zero1_equiv max_param_diff={dp_:.2e}", dp_ < 1e-5)


def sp_equivalence():
    """Sequence parallelism: same loss as plain TP."""
    pcfg0 = ParallelConfig(tp=2, dp=2)
    pcfg1 = ParallelConfig(tp=2, dp=2, use_sp=True)
    tcfg = TrainConfig(grad_clip=1e9, warmup_steps=1, total_steps=10)
    cfg = _cfg("stablelm-3b", "ladder")
    batch = _batch(cfg)
    p, s, _ = tpmod.init_train_state(cfg, pcfg0, jax.random.key(0))
    f0, *_ = tpmod.build_train_step(cfg, MESH, pcfg0, tcfg)
    f1, *_ = tpmod.build_train_step(cfg, MESH, pcfg1, tcfg)
    with compat.set_mesh(MESH):
        a = jax.jit(f0)(p, s, batch, jnp.zeros((), jnp.int32))
        b = jax.jit(f1)(jax.tree.map(jnp.copy, p), opt.adamw_init(p), batch,
                        jnp.zeros((), jnp.int32))
    dl = abs(float(a[2]["loss"]) - float(b[2]["loss"]))
    check(f"sp_equiv dloss={dl:.2e}", dl < 1e-4)


def padded_heads():
    """tp > n_kv (replication) and MHA padding: sharded == single device."""
    pcfg = ParallelConfig(tp=4, dp=1)
    mesh4 = compat.make_mesh((1, 4), ("data", "model"))
    tcfg = TrainConfig(grad_clip=1e9, warmup_steps=1, total_steps=10)
    # llava reduced: n_kv=1 < tp=4 -> replication; whisper: MHA padding
    for arch in ["llava-next-mistral-7b", "whisper-small"]:
        cfg = _cfg(arch, "ladder")
        params = tfm.init_params(cfg, jax.random.key(0))
        prepared, masks = sharding.prepare_params_for_tp(params, cfg,
                                                         pcfg.tp)
        batch = _batch(cfg, b=2)
        loss_ref, _ = tpmod.lm_loss(cfg, params, batch, NULL_ENV, tcfg, True)
        loss_pad, _ = tpmod.lm_loss(cfg, prepared, batch, NULL_ENV, tcfg,
                                    True)
        step_fn, *_ = tpmod.build_train_step(cfg, mesh4, pcfg, tcfg)
        with compat.set_mesh(mesh4):
            _, _, m = jax.jit(step_fn)(prepared, opt.adamw_init(prepared),
                                       batch, jnp.zeros((), jnp.int32))
        d1 = abs(float(loss_pad) - float(loss_ref))
        d2 = abs(float(m["loss"]) - float(loss_ref))
        check(f"padded_heads {arch} pad={d1:.2e} tp4={d2:.2e}",
              d1 < 1e-5 and d2 < 1e-4)


def flash_decode_seq_sharded():
    """Seq-sharded KV (flash decoding over 'data') == replicated decode."""
    from repro.serving import engine
    cfg = _cfg("stablelm-3b", "ladder")
    b, s0 = 2, 12
    params = tfm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (b, s0 + 1), 0,
                                cfg.vocab_size)

    # reference: single-device incremental decode
    caches, _ = engine.build_caches(cfg, b, 16, ParallelConfig(),
                                    for_decode=False)
    pos = jnp.broadcast_to(jnp.arange(s0)[None], (b, s0))
    hidden, caches, _ = tfm.forward(cfg, params, tokens[:, :s0], NULL_ENV,
                                    positions=pos, caches=caches)
    p1 = jnp.full((b, 1), s0, jnp.int32)
    h_ref, _, _ = tfm.forward(cfg, params, tokens[:, s0][:, None], NULL_ENV,
                              positions=p1, caches=caches, unroll=True)

    # seq-sharded: shard the 16-slot cache over data (2 shards of 8)
    pcfg = ParallelConfig(tp=2, dp=2, shard_seq_for_decode=True)
    env = tpmod.make_axis_env(pcfg)
    caches2, specs2 = engine.build_caches(cfg, b, 16, pcfg, for_decode=False,
                                          seq_shard_data=True)
    pspecs = sharding.param_pspecs(tfm.param_specs(cfg))

    def prefill_then_decode(params, tokens):
        caches_l, _ = engine.build_caches(cfg, b, 16, pcfg,
                                          for_decode=False,
                                          seq_shard_data=True)
        # inside shard_map the builder gives LOCAL slot counts already?
        # No: build caches OUTSIDE; here we only run the model.
        return None

    fn = compat.shard_map(
        lambda pr, tk, cs: _seqshard_body(cfg, env, pr, tk, cs, s0, b),
        MESH, (pspecs, P(), specs2), P())
    with compat.set_mesh(MESH):
        h_sh = jax.jit(fn)(params, tokens, caches2)
    d = float(jnp.max(jnp.abs(h_ref - h_sh)))
    check(f"flash_decode_seq_sharded d={d:.2e}", d < 1e-3)


def _seqshard_body(cfg, env, params, tokens, caches, s0, b):
    pos = jnp.broadcast_to(jnp.arange(s0)[None], (b, s0))
    hidden, caches, _ = tfm.forward(cfg, params, tokens[:, :s0], env,
                                    positions=pos, caches=caches)
    p1 = jnp.full((b, 1), s0, jnp.int32)
    h, _, _ = tfm.forward(cfg, params, tokens[:, s0][:, None], env,
                          positions=p1, caches=caches, unroll=True)
    return h


def pipeline_parity():
    """2-stage GPipe over 'pod' == single-stage stack, standard + ladder."""
    from repro.parallel import pp
    mesh_pp = compat.make_mesh((2, 2), ("pod", "model"))
    d, n_groups, bsz, s = 16, 4, 2, 8
    key = jax.random.key(0)
    w1 = jax.random.normal(key, (n_groups, d, 2 * d)) * 0.2
    w2 = jax.random.normal(jax.random.fold_in(key, 1),
                           (n_groups, 2 * d, d)) * 0.2
    params = dict(sub0=dict(w_in=w1, w_out=w2))
    x = jax.random.normal(jax.random.fold_in(key, 2), (2 * bsz, s, d))

    for mode in [ResidualMode.STANDARD, ResidualMode.LADDER]:
        def sub(p, h, st):
            y = jnp.tanh(h @ p["sub0"]["w_in"]) @ p["sub0"]["w_out"]
            return y, st, jnp.zeros((), jnp.float32)

        # single-device reference
        from repro.core import residual as topo
        carry = topo.init_carry(mode, x)
        carry, _ = topo.run_section(mode, [sub], params, carry, NULL_ENV)
        ref, _ = topo.finalize_carry(mode, carry, NULL_ENV)

        env = AxisEnv(model=None, pod="pod")

        def run_pp(params, xm):
            y, aux = pp.pipeline_stack(mode, [sub], params, xm, env,
                                       n_stages=2)
            return y

        xm = x.reshape(2, bsz, s, d)  # 2 microbatches
        fn = compat.shard_map(run_pp, mesh_pp,
                              (dict(sub0=dict(w_in=P("pod"),
                                              w_out=P("pod"))), P()),
                              P())
        with compat.set_mesh(mesh_pp):
            got = jax.jit(fn)(params, xm).reshape(2 * bsz, s, d)
        d_ = float(jnp.max(jnp.abs(got - ref)))
        check(f"pipeline_parity {mode.value} d={d_:.2e}", d_ < 1e-4)


def grad_compression():
    """EF-int8 pmean over a 2-axis: error feedback keeps long-run mean
    unbiased and single-step error bounded by the quantization step."""
    from repro.parallel import compression
    mesh = compat.make_mesh((4,), ("pod",))
    g = jax.random.normal(jax.random.key(0), (4, 64)) * 0.1

    def body(g):
        red, err = compression.compressed_pmean({"w": g}, "pod")
        return red["w"], err["w"]

    fn = compat.shard_map(body, mesh, P("pod"), (P("pod"), P("pod")))
    with compat.set_mesh(mesh):
        red, err = jax.jit(fn)(g)
    true_mean = jnp.broadcast_to(jnp.mean(g.reshape(4, 1, 64), axis=0),
                                 (4, 1, 64)).reshape(4, 64)
    rel = float(jnp.max(jnp.abs(red - true_mean)) /
                (jnp.max(jnp.abs(true_mean)) + 1e-9))
    # int8 per-block: relative error ~1/127 per element
    check(f"grad_compression rel_err={rel:.3f}", rel < 0.05)
    check("grad_compression error_feedback_shape",
          err.shape == g.shape)


def q8_weight_gather():
    """int8 FSDP weight gathers: forward within int8 quantization error
    of the bf16 reference (serving fit/bandwidth path, §Perf HC3)."""
    from repro.parallel import fsdp as fsdp_mod
    cfg = _cfg("stablelm-3b", "ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    params, _ = sharding.prepare_params_for_tp(params, cfg, 2)
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0,
                                cfg.vocab_size)
    h_ref, _, _ = tfm.forward(cfg, params, tokens, NULL_ENV)
    sec_pspecs = sharding.param_pspecs(params)["sections"]
    q8 = fsdp_mod.flatten_sections_host_q8(params["sections"], sec_pspecs,
                                           2, 2)
    meta = fsdp_mod.sections_meta_q8(
        jax.eval_shape(lambda: params)["sections"], sec_pspecs, 2, 2)
    pq8 = dict(params)
    pq8["sections"] = q8
    pspecs = dict(sharding.param_pspecs(params))
    pspecs["sections"] = fsdp_mod.flat_pspecs_q8(sec_pspecs)
    env = AxisEnv(model="model", data="data")
    gathers = fsdp_mod.make_section_gathers_q8(list(meta), env)

    def body(p, tokens):
        h, _, _ = tfm.forward(cfg, p, tokens, env, section_gathers=gathers)
        return h

    fn = compat.shard_map(body, MESH, (pspecs, P("data")), P("data"))
    with compat.set_mesh(MESH):
        h_q8 = jax.jit(fn)(pq8, tokens)
    rel = float(jnp.max(jnp.abs(h_q8 - h_ref)) /
                (jnp.max(jnp.abs(h_ref)) + 1e-9))
    check(f"q8_weight_gather rel_err={rel:.3f}", rel < 0.08)


def serve_continuous_batching():
    """Continuous-batching engine on a TP=2 x DP=2 mesh emits bit-identical
    tokens to isolated TP=1 decoding — ragged caches, per-slot prefill
    inserts into a data-sharded slot pool, per-request sampling."""
    from repro.serving.scheduler import (ContinuousServingEngine, Request,
                                         SamplingParams)
    cfg = _cfg("stablelm-3b", "ladder", d_model=64, n_heads=4, d_ff=128,
               vocab_size=256)
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, lp).tolist(),
                    max_new_tokens=g, sampling=s)
            for i, (lp, g, s) in enumerate([
                (5, 6, SamplingParams()),
                (11, 4, SamplingParams(temperature=0.7, top_k=12, seed=3)),
                (19, 5, SamplingParams(temperature=1.0, top_p=0.9, seed=8))])]

    def clone(r):
        return Request(rid=r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, sampling=r.sampling)

    iso = {}
    for r in reqs:
        e = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48)
        e.submit(clone(r))
        iso[r.rid] = e.run()[r.rid].tokens

    pcfg = ParallelConfig(tp=2, dp=2)
    p2, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
    eng = ContinuousServingEngine(cfg, p2, batch_slots=4, s_max=48,
                                  pcfg=pcfg, mesh=MESH)
    for r in reqs:
        eng.submit(clone(r))
    cont = eng.run()
    for rid, toks in iso.items():
        check(f"serve_cb tp2dp2 rid={rid}", toks == cont[rid].tokens)


def serve_paged_tp():
    """Paged-KV engine on a TP=2 mesh emits bit-identical tokens to the
    ragged TP=1 oracle — block-pool caches sharded over heads, chunked
    prefill, shared-prefix block reuse across requests."""
    from repro.serving.scheduler import (ContinuousServingEngine,
                                         PagedServingEngine, Request,
                                         SamplingParams)
    cfg = _cfg("stablelm-3b", "ladder", d_model=64, n_heads=4, d_ff=128,
               vocab_size=256)
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    reqs = [Request(rid=i,
                    prompt=(shared if i != 1 else []) +
                    rng.integers(0, cfg.vocab_size, lp).tolist(),
                    max_new_tokens=g, sampling=s)
            for i, (lp, g, s) in enumerate([
                (5, 6, SamplingParams()),
                (11, 4, SamplingParams(temperature=0.7, top_k=12, seed=3)),
                (7, 5, SamplingParams(temperature=1.0, top_p=0.9, seed=8))])]

    def clone(r):
        return Request(rid=r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, sampling=r.sampling)

    iso = {}
    for r in reqs:
        e = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48)
        e.submit(clone(r))
        iso[r.rid] = e.run()[r.rid].tokens

    pcfg = ParallelConfig(tp=2, dp=1)
    mesh2 = compat.make_mesh((2,), ("model",))
    p2, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
    eng = PagedServingEngine(cfg, p2, batch_slots=2, s_max=48, block_size=8,
                             max_prefill_tokens=16, pcfg=pcfg, mesh=mesh2)
    for r in reqs:
        eng.submit(clone(r))
    paged = eng.run()
    for rid, toks in iso.items():
        check(f"serve_paged tp2 rid={rid}", toks == paged[rid].tokens)
    check("serve_paged tp2 prefix reuse",
          eng.scheduler.prefix_hit_tokens > 0)


def serve_spec_tp():
    """Speculative decoding (ngram and draft-model modes) on a TP=2 mesh
    emits bit-identical tokens to the ragged TP=1 non-speculative oracle —
    verify forwards run K+1 queries per row through the sharded paged
    attention path; the draft model stays replicated."""
    from repro.serving.scheduler import (ContinuousServingEngine, Request,
                                         SamplingParams)
    from repro.serving.speculative import SpeculativePagedEngine
    cfg = _cfg("stablelm-3b", "ladder", d_model=64, n_heads=4, d_ff=128,
               vocab_size=256)
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    reqs = [Request(rid=i,
                    prompt=(shared if i != 1 else []) +
                    rng.integers(0, cfg.vocab_size, lp).tolist(),
                    max_new_tokens=g, sampling=s)
            for i, (lp, g, s) in enumerate([
                (5, 8, SamplingParams()),
                (11, 5, SamplingParams(temperature=0.7, top_k=12, seed=3)),
                (7, 6, SamplingParams(temperature=1.0, top_p=0.9, seed=8))])]

    def clone(r):
        return Request(rid=r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, sampling=r.sampling)

    iso = {}
    for r in reqs:
        e = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48)
        e.submit(clone(r))
        iso[r.rid] = e.run()[r.rid].tokens

    pcfg = ParallelConfig(tp=2, dp=1)
    mesh2 = compat.make_mesh((2,), ("model",))
    p2, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
    dcfg = cfg.reduced(n_layers=2)
    dparams = tfm.init_params(dcfg, jax.random.key(7))
    for spec_mode in ("ngram", "draft"):
        kw = dict(draft_cfg=dcfg, draft_params=dparams) \
            if spec_mode == "draft" else {}
        eng = SpeculativePagedEngine(
            cfg, p2, batch_slots=2, s_max=48, block_size=8,
            max_prefill_tokens=16, pcfg=pcfg, mesh=mesh2,
            spec_mode=spec_mode, spec_k=3, **kw)
        for r in reqs:
            eng.submit(clone(r))
        got = eng.run()
        for rid, toks in iso.items():
            check(f"serve_spec tp2 {spec_mode} rid={rid}",
                  toks == got[rid].tokens)
        check(f"serve_spec tp2 {spec_mode} verified",
              eng.stats()["verify_forwards"] > 0)


def serve_kernel_tp():
    """Paged engine with the block-table-native Pallas paged-attention
    kernel (use_pallas=True, interpret mode) on a TP=2 mesh emits
    bit-identical tokens to the ragged TP=1 gather oracle — for plain
    decode, chunked prefill AND speculative K+1 verification, the pool
    sharded over kv heads and the kernel's split-K stats combined per
    shard."""
    from repro.serving.scheduler import (ContinuousServingEngine,
                                         PagedServingEngine, Request,
                                         SamplingParams)
    from repro.serving.speculative import SpeculativePagedEngine
    cfg = _cfg("stablelm-3b", "ladder", d_model=64, n_heads=4, d_ff=128,
               vocab_size=256)
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    reqs = [Request(rid=i,
                    prompt=(shared if i != 1 else []) +
                    rng.integers(0, cfg.vocab_size, lp).tolist(),
                    max_new_tokens=g, sampling=s)
            for i, (lp, g, s) in enumerate([
                (5, 6, SamplingParams()),
                (11, 4, SamplingParams(temperature=0.7, top_k=12, seed=3)),
                (7, 5, SamplingParams(temperature=1.0, top_p=0.9, seed=8))])]

    def clone(r):
        return Request(rid=r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, sampling=r.sampling)

    iso = {}
    for r in reqs:
        e = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48)
        e.submit(clone(r))
        iso[r.rid] = e.run()[r.rid].tokens

    pcfg = ParallelConfig(tp=2, dp=1)
    mesh2 = compat.make_mesh((2,), ("model",))
    p2, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
    eng = PagedServingEngine(cfg, p2, batch_slots=2, s_max=48, block_size=8,
                             max_prefill_tokens=16, pcfg=pcfg, mesh=mesh2,
                             use_pallas=True)
    for r in reqs:
        eng.submit(clone(r))
    paged = eng.run()
    for rid, toks in iso.items():
        check(f"serve_kernel tp2 rid={rid}", toks == paged[rid].tokens)

    eng = SpeculativePagedEngine(
        cfg, p2, batch_slots=2, s_max=48, block_size=8,
        max_prefill_tokens=16, pcfg=pcfg, mesh=mesh2, use_pallas=True,
        spec_mode="ngram", spec_k=3)
    for r in reqs:
        eng.submit(clone(r))
    spec = eng.run()
    for rid, toks in iso.items():
        check(f"serve_kernel tp2 spec rid={rid}", toks == spec[rid].tokens)
    check("serve_kernel tp2 spec verified",
          eng.stats()["verify_forwards"] > 0)


def serve_memory_tp():
    """KV memory tiers on a TP=2 mesh: a tiny oversubscribed pool whose
    rows are preempted (blocks swapped to host, sharded over kv heads)
    and resumed emits bit-identical tokens to a roomy never-preempting
    TP=2 run — for the plain paged engine AND the speculative engine, fp
    pools; the int8 pool's preempted run must match its own roomy int8
    run bit-exactly (quantized bytes + scales move verbatim, TP-local
    shards each swap their own head slice)."""
    from repro.serving.scheduler import (PagedServingEngine, Request,
                                         SamplingParams)
    from repro.serving.speculative import SpeculativePagedEngine
    cfg = _cfg("stablelm-3b", "ladder", d_model=64, n_heads=4, d_ff=128,
               vocab_size=256)
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, lp).tolist(),
                    max_new_tokens=g, sampling=s)
            for i, (lp, g, s) in enumerate([
                (9, 8, SamplingParams()),
                (11, 6, SamplingParams(temperature=0.7, top_k=12, seed=3)),
                (7, 7, SamplingParams(temperature=1.0, top_p=0.9, seed=8)),
                (13, 5, SamplingParams())])]

    def clone(r):
        return Request(rid=r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, sampling=r.sampling)

    def run(engine):
        for r in reqs:
            engine.submit(clone(r))
        return {rid: f.tokens for rid, f in engine.run().items()}

    pcfg = ParallelConfig(tp=2, dp=1)
    mesh2 = compat.make_mesh((2,), ("model",))
    p2, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
    tight_kw = dict(batch_slots=3, s_max=48, block_size=4, num_blocks=8,
                    oversubscribe=2.5, pcfg=pcfg, mesh=mesh2)
    roomy_kw = dict(batch_slots=3, s_max=48, block_size=4, pcfg=pcfg,
                    mesh=mesh2)

    for quant in ("fp", "int8"):
        want = run(PagedServingEngine(cfg, p2, kv_quant=quant, **roomy_kw))
        eng = PagedServingEngine(cfg, p2, kv_quant=quant, **tight_kw)
        got = run(eng)
        check(f"serve_memory tp2 {quant} preempted",
              eng.stats()["preemptions"] > 0)
        for rid, toks in want.items():
            check(f"serve_memory tp2 {quant} rid={rid}",
                  toks == got[rid])

    spec_want = run(PagedServingEngine(cfg, p2, **roomy_kw))
    eng = SpeculativePagedEngine(cfg, p2, spec_mode="ngram", spec_k=3,
                                 **tight_kw)
    spec_got = run(eng)
    check("serve_memory tp2 spec preempted",
          eng.stats()["preemptions"] > 0)
    check("serve_memory tp2 spec verified",
          eng.stats()["verify_forwards"] > 0)
    for rid, toks in spec_want.items():
        check(f"serve_memory tp2 spec rid={rid}", toks == spec_got[rid])


def serve_comm_tp():
    """Overlapped TP AllReduce on a real TP=2 mesh — the comm-correctness
    gate of DESIGN.md §Communication overlap, two levels:

    collective level: the chunked ppermute ring must be bit-equal to
    ``jax.lax.psum`` at tp=2 (one commutative IEEE add) and to its
    host-side simulator; the int8 ring must match ITS simulator bit-exactly
    and stay within the analytic quantization bound of the fp sum.

    engine level: for every residual mode (standard / ladder / desync2) a
    ``PagedServingEngine`` with ``comm_overlap=True`` must stream
    bit-identical tokens to the same engine with overlap off — greedy and
    seeded-sampled requests, fp and int8 KV pools, plain and speculative
    engines; ladder additionally against the TP=1 iso oracle."""
    from repro.parallel.overlap import (chunk_bounds,
                                        compressed_ring_all_reduce,
                                        ring_all_reduce,
                                        simulate_compressed_all_reduce,
                                        simulate_ring_all_reduce)
    from repro.quant import BLOCK, quantize_int8
    from repro.serving.scheduler import (ContinuousServingEngine,
                                         PagedServingEngine, Request,
                                         SamplingParams)
    from repro.serving.speculative import SpeculativePagedEngine

    # ---- collective level -------------------------------------------------
    mesh2 = compat.make_mesh((2,), ("model",))
    rng = np.random.default_rng(0)
    shards = jnp.asarray(rng.normal(size=(2, 3, 7, 33)), jnp.float32)
    for chunks in (1, 3, 5):
        def ring(v, c=chunks):
            return ring_all_reduce(v, "model", chunks=c)

        def cring(v, c=chunks):
            return compressed_ring_all_reduce(v, "model", chunks=c)

        def psum(v):
            return jax.lax.psum(v, "model")

        with compat.set_mesh(mesh2):
            got_ring = jax.jit(compat.shard_map(
                ring, mesh2, P("model"), P("model")))(shards)
            got_psum = jax.jit(compat.shard_map(
                psum, mesh2, P("model"), P("model")))(shards)
            got_c = jax.jit(compat.shard_map(
                cring, mesh2, P("model"), P("model")))(shards)
        check(f"serve_comm ring==psum tp2 chunks={chunks}",
              np.array_equal(np.asarray(got_ring), np.asarray(got_psum)))
        check(f"serve_comm ring==simulator chunks={chunks}",
              np.array_equal(np.asarray(got_ring), np.asarray(
                  simulate_ring_all_reduce(shards, chunks=chunks))))
        # compressed: cross-shard bit-identity is the contract; vs the
        # eager host simulator allow <=1-ulp FMA slack (jit may fuse the
        # dequant multiply+add into one rounding, the simulator rounds
        # twice — same reason tests/test_collectives.py uses allclose)
        check(f"serve_comm compressed shard-identical chunks={chunks}",
              np.array_equal(np.asarray(got_c)[0], np.asarray(got_c)[1]))
        sim_c = np.asarray(simulate_compressed_all_reduce(shards,
                                                          chunks=chunks))
        check(f"serve_comm compressed~=simulator chunks={chunks}",
              bool(np.allclose(np.asarray(got_c), sim_c, rtol=1e-6,
                               atol=1e-6)))
        flat = np.asarray(shards.reshape(2, -1))
        bound = np.zeros(flat.shape[1])
        for start, size in chunk_bounds(flat.shape[1], chunks):
            for j in range(2):
                _, s = quantize_int8(jnp.asarray(flat[j, start:start + size]))
                bound[start:start + size] += \
                    0.5 * np.asarray(jnp.repeat(s, BLOCK)[:size])
        err = np.abs(np.asarray(got_c[0]).reshape(-1) - flat.sum(0))
        check(f"serve_comm compressed bounded chunks={chunks}",
              bool(np.all(err <= bound + 1e-6)))

    # ---- engine level -----------------------------------------------------
    rng = np.random.default_rng(0)
    shared = rng.integers(0, 256, 16).tolist()

    def make_reqs(vocab):
        return [Request(rid=i,
                        prompt=(shared if i != 1 else []) +
                        rng.integers(0, vocab, lp).tolist(),
                        max_new_tokens=g, sampling=s)
                for i, (lp, g, s) in enumerate([
                    (5, 6, SamplingParams()),
                    (11, 4, SamplingParams(temperature=0.7, top_k=12,
                                           seed=3)),
                    (7, 5, SamplingParams(temperature=1.0, top_p=0.9,
                                          seed=8))])]

    def clone(r):
        return Request(rid=r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, sampling=r.sampling)

    def run(engine, reqs):
        for r in reqs:
            engine.submit(clone(r))
        return {rid: f.tokens for rid, f in engine.run().items()}

    pcfg = ParallelConfig(tp=2, dp=1)
    for mode in ("standard", "ladder", "desync2"):
        cfg = _cfg("stablelm-3b", mode, d_model=64, n_heads=4, d_ff=128,
                   vocab_size=256)
        params = tfm.init_params(cfg, jax.random.key(0))
        reqs = make_reqs(cfg.vocab_size)
        p2, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
        kw = dict(batch_slots=2, s_max=48, block_size=8,
                  max_prefill_tokens=16, pcfg=pcfg, mesh=mesh2)

        for kv_quant in ("fp", "int8"):
            off = run(PagedServingEngine(cfg, p2, kv_quant=kv_quant, **kw),
                      reqs)
            on = run(PagedServingEngine(cfg, p2, kv_quant=kv_quant,
                                        comm_overlap=True, **kw), reqs)
            for rid, toks in off.items():
                check(f"serve_comm {mode} {kv_quant} rid={rid}",
                      toks == on[rid])

        if mode == "ladder":
            # overlap-on TP=2 against the TP=1 iso oracle as well: the
            # ring must not just be self-consistent but *correct*
            iso = {}
            for r in reqs:
                e = ContinuousServingEngine(cfg, params, batch_slots=1,
                                            s_max=48)
                e.submit(clone(r))
                iso[r.rid] = e.run()[r.rid].tokens
            on = run(PagedServingEngine(cfg, p2, comm_overlap=True, **kw),
                     reqs)
            for rid, toks in iso.items():
                check(f"serve_comm ladder vs-iso rid={rid}",
                      toks == on[rid])

        spec_off = SpeculativePagedEngine(cfg, p2, spec_mode="ngram",
                                          spec_k=3, **kw)
        got_off = run(spec_off, reqs)
        spec_on = SpeculativePagedEngine(cfg, p2, spec_mode="ngram",
                                         spec_k=3, comm_overlap=True, **kw)
        got_on = run(spec_on, reqs)
        check(f"serve_comm {mode} spec verified",
              spec_on.stats()["verify_forwards"] > 0)
        for rid, toks in got_off.items():
            check(f"serve_comm {mode} spec rid={rid}", toks == got_on[rid])


def serve_tuned_tp():
    """Kernel-tuning dispatch on a real TP=2 mesh: the tuned launch
    geometry (kernels/autotune.py via engine.build_paged_steps's static
    (phase, occupancy-bucket) key) only re-tiles the SAME f32 online-
    softmax accumulation, so a tuned-on engine must stream bit-identical
    tokens to tuned-off for every residual mode — plain decode, chunked
    prefill and speculative K+1 verify.  Ladder additionally checks the
    fused dequant+RMSNorm consumer (comm_fuse_norm): the Pallas kernel
    against its jnp oracle, token-for-token."""
    from repro.serving.scheduler import (PagedServingEngine, Request,
                                         SamplingParams)
    from repro.serving.speculative import SpeculativePagedEngine

    rng = np.random.default_rng(0)
    shared = rng.integers(0, 256, 16).tolist()
    reqs = [Request(rid=i,
                    prompt=(shared if i != 1 else []) +
                    rng.integers(0, 256, lp).tolist(),
                    max_new_tokens=g, sampling=s)
            for i, (lp, g, s) in enumerate([
                (5, 6, SamplingParams()),
                (11, 4, SamplingParams(temperature=0.7, top_k=12, seed=3)),
                (7, 5, SamplingParams(temperature=1.0, top_p=0.9, seed=8))])]

    def clone(r):
        return Request(rid=r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, sampling=r.sampling)

    def run(engine):
        for r in reqs:
            engine.submit(clone(r))
        return {rid: f.tokens for rid, f in engine.run().items()}

    pcfg = ParallelConfig(tp=2, dp=1)
    mesh2 = compat.make_mesh((2,), ("model",))
    for mode in ("standard", "ladder", "desync2"):
        cfg = _cfg("stablelm-3b", mode, d_model=64, n_heads=4, d_ff=128,
                   vocab_size=256)
        params = tfm.init_params(cfg, jax.random.key(0))
        p2, _ = sharding.prepare_params_for_tp(params, cfg, pcfg.tp)
        kw = dict(batch_slots=2, s_max=48, block_size=8,
                  max_prefill_tokens=16, pcfg=pcfg, mesh=mesh2,
                  use_pallas=True)

        off = run(PagedServingEngine(cfg, p2, tuned=False, **kw))
        on = run(PagedServingEngine(cfg, p2, tuned=True, **kw))
        for rid, toks in off.items():
            check(f"serve_tuned {mode} rid={rid}", toks == on[rid])

        spec_off = run(SpeculativePagedEngine(cfg, p2, spec_mode="ngram",
                                              spec_k=3, tuned=False, **kw))
        eng = SpeculativePagedEngine(cfg, p2, spec_mode="ngram", spec_k=3,
                                     tuned=True, **kw)
        spec_on = run(eng)
        check(f"serve_tuned {mode} spec verified",
              eng.stats()["verify_forwards"] > 0)
        for rid, toks in spec_off.items():
            check(f"serve_tuned {mode} spec rid={rid}",
                  toks == spec_on[rid])

        if mode == "ladder":
            # fused dequant+RMSNorm: Pallas kernel vs jnp oracle over the
            # SAME deferred int8 pending images — bit-identical tokens
            fkw = dict(kw, comm_fuse_norm=True)
            jnp_norm = run(PagedServingEngine(
                cfg, p2, **dict(fkw, use_pallas=False)))
            pal_norm = run(PagedServingEngine(cfg, p2, **fkw))
            for rid, toks in jnp_norm.items():
                check(f"serve_tuned fuse_norm rid={rid}",
                      toks == pal_norm[rid])


CHECKS = dict(tp=tp_equivalence, fsdp=fsdp_equivalence,
              zero1=zero1_equivalence, sp=sp_equivalence,
              padded=padded_heads, flashdec=flash_decode_seq_sharded,
              pp=pipeline_parity, compress=grad_compression,
              q8=q8_weight_gather, serve_cb=serve_continuous_batching,
              serve_paged=serve_paged_tp, serve_spec=serve_spec_tp,
              serve_kernel=serve_kernel_tp, serve_memory=serve_memory_tp,
              serve_comm=serve_comm_tp, serve_tuned=serve_tuned_tp)

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    for name, fn in CHECKS.items():
        if which in (name, "all"):
            fn()
    sys.exit(0 if OK else 1)
