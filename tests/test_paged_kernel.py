"""Paged-attention kernel tests (DESIGN.md §Paged-attention kernel).

Layers, bottom-up:

* kernel vs gather oracle — interpret-mode equivalence of
  ``kernels.paged_attention`` against the ``paged_view`` +
  ``_cached_attention`` read it replaces, swept over block_size x GQA
  group x ragged kv_len x Q (plain decode and K+1 verify shapes) x
  softcap, including inactive (-1) rows and permuted/shared block tables.
* split-K — partial-stats combine is invariant to the split count.
* host-side table slicing — the engine feeds the jitted steps bucketed
  live-width tables, so the oracle path's gather traffic tracks occupancy.
* engine equivalence — ``use_pallas=True`` emits token streams
  bit-identical to the gather path for ladder/standard/desync2, chunked
  prefill, mixed sampling, and speculative decoding with both drafters
  (the TP=2 group lives in tests/distributed_impl.py: ``serve_kernel``).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, ResidualMode
from repro.kernels.paged_attention import paged_attention
from repro.models import transformer as tfm
from repro.models.attention import _cached_attention
from repro.parallel.collectives import NULL_ENV
from repro.serving.kv_cache import (
    PagedKVCache,
    make_paged_kv_cache,
    paged_update,
    paged_view,
)
from repro.serving.scheduler import (
    ContinuousServingEngine,
    PagedServingEngine,
    Request,
    SamplingParams,
)


# ---------------------------------------------------------------------------
# kernel vs the gather oracle (interpret mode)
# ---------------------------------------------------------------------------


def _pool_case(seed, b, q_len, hkv, g, hd, bs, num_blocks, m):
    """Random pool + per-row permuted block tables + q at given positions."""
    key = jax.random.key(seed)
    hq = hkv * g
    q = jax.random.normal(key, (b, q_len, hq, hd), jnp.float32)
    k = jax.random.normal(
        jax.random.fold_in(key, 1), (hkv, num_blocks * bs, hd), jnp.float32
    )
    v = jax.random.normal(
        jax.random.fold_in(key, 2), (hkv, num_blocks * bs, hd), jnp.float32
    )
    rng = np.random.default_rng(seed)
    bt = np.zeros((b, m), np.int32)
    for row in range(b):  # rows may share blocks (prefix reuse)
        bt[row] = rng.choice(num_blocks, size=m, replace=False)
    bt[1:, 0] = bt[0, 0]
    return q, k, v, jnp.asarray(bt)


def _oracle(q, k, v, bt, qpos, *, scale, bs, softcap=0.0):
    """The read path the kernel replaces: gather the logical view, then the
    masked softmax read (paged_view + _cached_attention)."""
    cache = PagedKVCache(k=k, v=v, block_size=bs)
    view = paged_view(cache, bt)
    return _cached_attention(q * scale, view, qpos, NULL_ENV, softcap=softcap)


@pytest.mark.parametrize(
    "bs,g,q_len,softcap",
    [
        (8, 1, 1, 0.0),  # MHA decode
        (8, 2, 1, 0.0),  # GQA decode
        (4, 4, 1, 30.0),  # GQA decode + softcap
        (8, 2, 5, 0.0),  # K+1 speculative verify
        (16, 1, 4, 20.0),  # verify + softcap, bigger blocks
    ],
)
def test_kernel_matches_gather_oracle(bs, g, q_len, softcap):
    b, hkv, hd, num_blocks, m = 3, 2, 32, 16, 4
    q, k, v, bt = _pool_case(0, b, q_len, hkv, g, hd, bs, num_blocks, m)
    scale = hd**-0.5
    # ragged: every row at a different kv length; verify rows additionally
    # at different klen (trailing queries padded to -1)
    base = jnp.asarray([2, bs + 3, m * bs - q_len])[:b]
    ar = jnp.arange(q_len)[None, :]
    klen = jnp.asarray([q_len, max(1, q_len - 2), 1])[:b]
    qpos = jnp.where(ar < klen[:, None], base[:, None] + ar, -1)
    qpos = qpos.astype(jnp.int32)

    got = paged_attention(
        q,
        k,
        v,
        bt,
        qpos,
        scale=scale,
        block_size=bs,
        softcap=softcap,
        interpret=True,
    )
    want = _oracle(q, k, v, bt, qpos, scale=scale, bs=bs, softcap=softcap)
    valid = (qpos >= 0)[:, :, None, None]
    np.testing.assert_allclose(
        np.where(valid, got, 0),
        np.where(valid, want, 0),
        atol=2e-5,
        rtol=2e-5,
    )


def test_kernel_inactive_rows_and_single_block():
    """A fully inactive row (all positions -1) yields zeros — never read by
    the host, but it must not poison the softmax stats (NaN/inf)."""
    b, hkv, g, hd, bs = 2, 1, 2, 16, 4
    q, k, v, bt = _pool_case(1, b, 1, hkv, g, hd, bs, 8, 1)
    qpos = jnp.asarray([[0], [-1]], jnp.int32)
    got = paged_attention(
        q, k, v, bt, qpos, scale=hd**-0.5, block_size=bs, interpret=True
    )
    assert bool(jnp.all(jnp.isfinite(got)))
    np.testing.assert_array_equal(np.asarray(got[1]), 0.0)
    want = _oracle(q, k, v, bt, qpos, scale=hd**-0.5, bs=bs)
    np.testing.assert_allclose(got[0], want[0], atol=2e-5, rtol=2e-5)


def test_kernel_split_k_invariance():
    """Partial (m, l, acc) stats merge to the same output for any split
    count — the host-side combine contract flash decoding relies on."""
    b, hkv, g, hd, bs, m = 2, 2, 2, 32, 8, 6
    q, k, v, bt = _pool_case(2, b, 3, hkv, g, hd, bs, 16, m)
    qpos = jnp.asarray([[10, 11, 12], [m * bs - 3, m * bs - 2, -1]], jnp.int32)
    outs = [
        paged_attention(
            q,
            k,
            v,
            bt,
            qpos,
            scale=hd**-0.5,
            block_size=bs,
            num_splits=ns,
            interpret=True,
        )
        for ns in (1, 2, 3, 6)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(
            np.asarray(outs[0]), np.asarray(o), atol=1e-6, rtol=1e-6
        )


def test_kernel_reads_only_written_blocks():
    """Poisoning pool blocks OUTSIDE every row's table (and inside the
    table but past each row's kv length) must not change the output: the
    kernel's block walk + position mask never touches them."""
    b, hkv, g, hd, bs, nb, m = 2, 1, 1, 16, 4, 12, 3
    q, k, v, bt = _pool_case(3, b, 1, hkv, g, hd, bs, nb, m)
    qpos = jnp.asarray([[5], [9]], jnp.int32)
    ref = paged_attention(
        q, k, v, bt, qpos, scale=hd**-0.5, block_size=bs, interpret=True
    )
    used = set(np.asarray(bt).ravel().tolist())
    poison_k, poison_v = np.array(k), np.array(v)
    for blk in set(range(nb)) - used:
        lo = blk * bs
        hi = lo + bs
        poison_k[:, lo:hi] = np.nan
        poison_v[:, lo:hi] = np.nan
    got = paged_attention(
        jnp.asarray(q),
        jnp.asarray(poison_k),
        jnp.asarray(poison_v),
        bt,
        qpos,
        scale=hd**-0.5,
        block_size=bs,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# ---------------------------------------------------------------------------
# host-side table slicing (the oracle-path traffic fix)
# ---------------------------------------------------------------------------


def _tiny_cfg(mode):
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=256
    )
    return cfg.replace(residual_mode=ResidualMode(mode))


def test_engine_slices_block_table_to_live_width():
    """The decode step must see a power-of-two bucket of the max in-use
    block count, not the static max_blocks table width."""
    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    # s_max=64 at block_size 4 -> max_blocks=16, but a 5-token prompt with
    # 3 generated tokens touches only ceil(8/4)=2 blocks
    eng = PagedServingEngine(cfg, params, batch_slots=2, s_max=64, block_size=4)
    eng.submit(
        Request(
            rid=0,
            prompt=list(range(5)),
            max_new_tokens=3,
            sampling=SamplingParams(),
        )
    )
    assert eng.max_blocks == 16
    widths = []
    while eng.has_work():
        eng.step()
        live = eng.scheduler.decoding_slots()
        if live:
            widths.append(eng._bt_width(live))
    assert widths and all(w == 2 for w in widths)


# ---------------------------------------------------------------------------
# engine equivalences (kernel on == gather oracle, bit-identical tokens)
# ---------------------------------------------------------------------------


def _trace(vocab, rng):
    shared = rng.integers(0, vocab, 16).tolist()  # 2 full blocks at bs=8
    cases = [
        (shared + rng.integers(0, vocab, 5).tolist(), 5, SamplingParams()),
        (
            rng.integers(0, vocab, 9).tolist(),
            4,
            SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=7),
        ),
        (shared + rng.integers(0, vocab, 3).tolist(), 4, SamplingParams()),
    ]
    return [
        Request(rid=i, prompt=p, max_new_tokens=g, sampling=sp)
        for i, (p, g, sp) in enumerate(cases)
    ]


def _clone(r):
    return Request(
        rid=r.rid,
        prompt=list(r.prompt),
        max_new_tokens=r.max_new_tokens,
        sampling=r.sampling,
    )


def _run_paged(cfg, params, reqs, *, use_pallas, spec_mode=None, **kw):
    if spec_mode:
        from repro.serving.speculative import SpeculativePagedEngine

        eng = SpeculativePagedEngine(
            cfg,
            params,
            batch_slots=2,
            s_max=48,
            block_size=8,
            max_prefill_tokens=16,
            use_pallas=use_pallas,
            spec_mode=spec_mode,
            spec_k=3,
            **kw,
        )
    else:
        eng = PagedServingEngine(
            cfg,
            params,
            batch_slots=2,
            s_max=48,
            block_size=8,
            max_prefill_tokens=16,
            use_pallas=use_pallas,
            **kw,
        )
    eng.submit(_clone(reqs[0]))
    eng.submit(_clone(reqs[1]))
    eng.step()
    for r in reqs[2:]:
        eng.submit(_clone(r))
    fin = eng.run()
    return {rid: f.tokens for rid, f in fin.items()}, eng


@pytest.mark.parametrize("mode", ["ladder", "standard", "desync2"])
def test_paged_engine_kernel_matches_gather(mode):
    """Chunked prefill + mixed-age decode through the kernel emits token
    streams bit-identical to the gather oracle, all residual modes."""
    cfg = _tiny_cfg(mode)
    params = tfm.init_params(cfg, jax.random.key(0))
    reqs = _trace(cfg.vocab_size, np.random.default_rng(0))
    want, _ = _run_paged(cfg, params, reqs, use_pallas=False)
    got, _ = _run_paged(cfg, params, reqs, use_pallas=True)
    assert got == want


@pytest.mark.parametrize("spec_mode", ["ngram", "draft"])
def test_speculative_verify_kernel_matches_plain_decode(spec_mode):
    """K+1 verify through the kernel stays bit-identical to plain decode
    (the ragged oracle), for both drafters."""
    cfg = _tiny_cfg("ladder")
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    # repetitive prompts so ngram drafting actually engages
    reqs = [
        Request(
            rid=0,
            prompt=[5, 6, 7, 5, 6, 7, 5, 6],
            max_new_tokens=6,
            sampling=SamplingParams(),
        ),
        Request(
            rid=1,
            prompt=rng.integers(0, cfg.vocab_size, 9).tolist(),
            max_new_tokens=4,
            sampling=SamplingParams(temperature=0.9, top_k=12, seed=3),
        ),
        Request(
            rid=2,
            prompt=[5, 6, 7, 5, 6, 7],
            max_new_tokens=5,
            sampling=SamplingParams(),
        ),
    ]
    iso = {}
    for r in reqs:
        e = ContinuousServingEngine(cfg, params, batch_slots=1, s_max=48)
        e.submit(_clone(r))
        iso[r.rid] = e.run()[r.rid].tokens

    kw = {}
    if spec_mode == "draft":
        dcfg = cfg.reduced(n_layers=1)
        kw = dict(
            draft_cfg=dcfg,
            draft_params=tfm.init_params(dcfg, jax.random.key(7)),
        )
    got, eng = _run_paged(
        cfg, params, reqs, use_pallas=True, spec_mode=spec_mode, **kw
    )
    assert got == iso
    assert eng.stats()["verify_forwards"] > 0


def test_paged_update_then_kernel_round_trip():
    """Scatter + kernel read: writes through the block table land where the
    kernel's walk finds them (no gather view in between)."""
    bs, hkv, hd = 4, 1, 16
    cache = make_paged_kv_cache(
        num_blocks=6, block_size=bs, hkv=hkv, hd=hd, dtype=jnp.float32
    )
    bt = jnp.asarray([[3, 1, 4]], jnp.int32)
    key = jax.random.key(5)
    kv_len = 9
    kn = jax.random.normal(key, (1, kv_len, hkv, hd))
    vn = jax.random.normal(jax.random.fold_in(key, 1), (1, kv_len, hkv, hd))
    pos = jnp.arange(kv_len)[None]
    cache = paged_update(cache, kn, vn, pos, bt)
    q = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, hkv, hd))
    qpos = jnp.asarray([[kv_len - 1]], jnp.int32)
    got = paged_attention(
        q,
        cache.k,
        cache.v,
        bt,
        qpos,
        scale=hd**-0.5,
        block_size=bs,
        interpret=True,
    )
    want = _oracle(q, cache.k, cache.v, bt, qpos, scale=hd**-0.5, bs=bs)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )
