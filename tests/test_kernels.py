"""Per-kernel allclose vs the pure-jnp oracles, over shape/dtype sweeps.

All kernels run in interpret mode on CPU (the kernel body executes verbatim,
so the TPU code path's math is what is being validated)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6 import rwkv6
from repro.kernels.ssm_scan import ssm_scan


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("s,hd,g,window", [
    (128, 64, 1, 0), (256, 64, 2, 0), (192, 32, 1, 0),   # GQA + ragged
    (256, 64, 1, 64), (384, 128, 4, 128),                # sliding window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(s, hd, g, window, dtype):
    bkv = 2
    bh = bkv * g
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (bh, s, hd), dtype)
    k = jax.random.normal(k2, (bkv, s, hd), dtype)
    v = jax.random.normal(k3, (bkv, s, hd), dtype)
    scale = hd ** -0.5
    got = flash_attention(q, k, v, scale=scale, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=scale, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_flash_attention_softcap(softcap):
    q = jax.random.normal(jax.random.key(1), (2, 128, 64))
    k = jax.random.normal(jax.random.key(2), (2, 128, 64))
    v = jax.random.normal(jax.random.key(3), (2, 128, 64))
    got = flash_attention(q, k, v, scale=0.125, softcap=softcap,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=0.125, softcap=softcap)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [(4, 64), (2, 7, 256), (3, 5, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    w = jax.random.normal(jax.random.key(1), (shape[-1],), dtype) * 0.1
    got = rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("s,h,n,hd,chunk", [
    (64, 2, 16, 32, 16), (96, 1, 8, 16, 32), (128, 3, 32, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan(s, h, n, hd, chunk, dtype):
    b = 2
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    bm = jax.random.normal(ks[1], (b, s, h, n), dtype) * 0.5
    cm = jax.random.normal(ks[2], (b, s, h, n), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h))) * 0.5
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    got_y, got_h = ssm_scan(x, bm, cm, dt, a_log, chunk=chunk,
                            interpret=True)
    want_y, want_h = ref.ssm_scan_ref(x, bm, cm, dt, a_log)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("s,h,hd,chunk", [(48, 2, 16, 16), (64, 1, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_kernel(s, h, hd, chunk, dtype):
    b = 2
    ks = jax.random.split(jax.random.key(0), 5)
    r = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, h, hd), dtype) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, hd), dtype) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)))  # (0,1)
    w = w.astype(dtype)
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    got_y, got_s = rwkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    want_y, want_s = ref.rwkv6_ref(r, k, v, w, u, s0)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-3, rtol=1e-3)


def test_model_scan_matches_kernel_path():
    """models.ssm mamba2 (chunked jnp) == sequential oracle; and the rwkv
    scan in models.rwkv == oracle — the model paths the kernels replace."""
    from repro.models.ssm import _ssd_chunked
    from repro.models.rwkv import wkv6_scan
    b, s, h, n, hd = 2, 64, 2, 16, 32
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, hd))
    bm = jax.random.normal(ks[1], (b, s, h, n)) * 0.5
    cm = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h))) * 0.5
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    y1, h1 = _ssd_chunked(x, bm, cm, dt, a_log, chunk=16)
    y2, h2 = ref.ssm_scan_ref(x, bm, cm, dt, a_log)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-4)

    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, hd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)))
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    y1, s1 = wkv6_scan(r, k, v, w, u, s0)
    y2, s2 = ref.rwkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-4)
