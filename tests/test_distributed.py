"""Multi-device equivalence tests (TP / FSDP / ZeRO-1 / SP / padded heads /
flash-decode / pipeline / compression).

These need 4 fake XLA devices set BEFORE jax initialises, so each group runs
in a subprocess (tests/distributed_impl.py) — the rest of the suite keeps
its single real device."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess suites; full tier only

IMPL = os.path.join(os.path.dirname(__file__), "distributed_impl.py")


def _run(which: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, IMPL, which], env=env,
                       capture_output=True, text=True, timeout=1500)
    print(r.stdout)
    print(r.stderr[-3000:] if r.returncode else "", file=sys.stderr)
    assert r.returncode == 0, f"{which} failed:\n{r.stdout}\n{r.stderr[-2000:]}"
    assert "FAIL" not in r.stdout


@pytest.mark.parametrize("which", ["tp", "fsdp", "zero1", "sp", "padded",
                                   "flashdec", "pp", "compress", "q8",
                                   "serve_cb", "serve_paged", "serve_spec",
                                   "serve_kernel", "serve_memory",
                                   "serve_comm", "serve_tuned"])
def test_distributed(which):
    _run(which)
