"""Serving correctness: prefill + decode against the KV cache must produce
the same next-token distribution as a from-scratch forward over the full
prefix — for every cache kind (full, ring/window, MLA, SSM, RWKV, cross)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, ParallelConfig, ResidualMode
from repro.models import transformer as tfm
from repro.models.model import build_model
from repro.parallel.collectives import NULL_ENV
from repro.serving import engine

PCFG = ParallelConfig(tp=1, dp=1)


def _greedy_from_hidden(cfg, params, hidden):
    logits = tfm.logits_shard(cfg, params, hidden[:, -1:])
    lf = logits[:, 0].astype(jnp.float32)
    col = jnp.arange(lf.shape[-1])
    lf = jnp.where(col < cfg.vocab_size, lf, -1e30)
    return jnp.argmax(lf, axis=-1)


@pytest.mark.parametrize("arch,mode", [
    ("stablelm-3b", "ladder"), ("stablelm-3b", "standard"),
    ("gemma3-4b", "ladder"),          # ring/window caches
    ("deepseek-v2-lite-16b", "ladder"),  # MLA compressed cache
    ("zamba2-2.7b", "ladder"),        # mamba state + shared attn cache
    ("rwkv6-7b", "ladder"),           # rwkv recurrent state
    ("llava-next-mistral-7b", "ladder"),
])
def test_prefill_decode_matches_full_forward(arch, mode):
    cfg = REGISTRY[arch].reduced(n_layers=4).replace(
        residual_mode=ResidualMode(mode))
    init, apply = build_model(cfg)
    params = init(jax.random.key(0))
    b, s0, n_new = 2, 12, 3
    total = s0 + n_new
    tokens = jax.random.randint(jax.random.key(1), (b, total), 0,
                                cfg.vocab_size)
    kw = {}
    patch_off = 0
    if cfg.family == "vlm":
        kw["frontend_embeds"] = jax.random.normal(
            jax.random.key(2), (b, cfg.num_patches, cfg.d_model)) * 0.02
        patch_off = cfg.num_patches

    # incremental: prefill s0 tokens, then decode n_new one by one
    s_max = total + patch_off
    caches, _ = engine.build_caches(cfg, b, s_max, PCFG, for_decode=False)
    pos = jnp.broadcast_to(jnp.arange(s0 + patch_off)[None],
                           (b, s0 + patch_off))
    hidden, caches, _ = tfm.forward(cfg, params, tokens[:, :s0], NULL_ENV,
                                    positions=pos, caches=caches, **kw)
    nxt_inc = []
    cur_tok = _greedy_from_hidden(cfg, params, hidden)
    for i in range(n_new):
        nxt_inc.append(np.asarray(cur_tok))
        p = jnp.full((b, 1), s0 + patch_off + i, jnp.int32)
        hidden, caches, _ = tfm.forward(
            cfg, params, tokens[:, s0 + i][:, None], NULL_ENV, positions=p,
            caches=caches, unroll=True)
        cur_tok = _greedy_from_hidden(cfg, params, hidden)
    nxt_inc.append(np.asarray(cur_tok))

    # reference: full forwards over growing prefixes
    nxt_ref = []
    for i in range(n_new + 1):
        hidden, _, _ = tfm.forward(cfg, params, tokens[:, :s0 + i],
                                   NULL_ENV, **kw)
        nxt_ref.append(np.asarray(_greedy_from_hidden(cfg, params, hidden)))

    np.testing.assert_array_equal(np.stack(nxt_inc), np.stack(nxt_ref))


def test_whisper_prefill_decode():
    cfg = REGISTRY["whisper-small"].reduced(n_layers=2)
    init, apply = build_model(cfg)
    params = init(jax.random.key(0))
    b, s0, n_new = 2, 8, 2
    total = s0 + n_new
    frames = jax.random.normal(jax.random.key(2),
                               (b, total * cfg.encoder_seq_ratio,
                                cfg.d_model)) * 0.02
    tokens = jax.random.randint(jax.random.key(1), (b, total), 0,
                                cfg.vocab_size)

    caches, _ = engine.build_caches(cfg, b, total, PCFG, for_decode=False)
    hidden, caches, _ = tfm.forward(cfg, params, tokens[:, :s0], NULL_ENV,
                                    caches=caches, frontend_embeds=frames)
    toks_inc = [np.asarray(_greedy_from_hidden(cfg, params, hidden))]
    for i in range(n_new):
        p = jnp.full((b, 1), s0 + i, jnp.int32)
        hidden, caches, _ = tfm.forward(
            cfg, params, tokens[:, s0 + i][:, None], NULL_ENV, positions=p,
            caches=caches, unroll=True)
        toks_inc.append(np.asarray(_greedy_from_hidden(cfg, params, hidden)))

    toks_ref = []
    for i in range(n_new + 1):
        hidden, _, _ = tfm.forward(cfg, params, tokens[:, :s0 + i],
                                   NULL_ENV, frontend_embeds=frames)
        toks_ref.append(np.asarray(_greedy_from_hidden(cfg, params, hidden)))
    np.testing.assert_array_equal(np.stack(toks_inc), np.stack(toks_ref))


def test_window_cache_ring_semantics():
    """Ring cache: decode far past the window only sees the last W keys."""
    from repro.serving.kv_cache import make_kv_cache, cache_update
    cache = make_kv_cache(1, 64, 1, 4, jnp.float32, window=8)
    assert cache.ring
    env = NULL_ENV
    for t in range(20):
        kv = jnp.full((1, 1, 1, 4), float(t))
        cache = cache_update(cache, kv, kv,
                             jnp.asarray([[t]], jnp.int32), env)
    # slots hold positions 12..19
    live = sorted(np.asarray(cache.slot_pos).tolist())
    assert live == list(range(12, 20))


def test_greedy_sampler_matches_argmax():
    from repro.serving import sampler
    logits = jax.random.normal(jax.random.key(0), (3, 128))
    got = sampler.greedy(logits, NULL_ENV, true_vocab=100)
    want = jnp.argmax(jnp.where(jnp.arange(128) < 100, logits, -1e30), -1)
    np.testing.assert_array_equal(got, want)
