"""Training substrate: optimizer math, LR schedule, loss goes down,
checkpoint-restart bitwise equivalence, data determinism."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, ResidualMode, TrainConfig
from repro.models import transformer as tfm
from repro.parallel import tp as tpmod
from repro.parallel.collectives import NULL_ENV
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.data import SyntheticLM


def test_adamw_against_reference():
    """Single-tensor AdamW vs a hand NumPy implementation, 5 steps."""
    cfg = TrainConfig(learning_rate=1e-2, weight_decay=0.1, beta1=0.9,
                      beta2=0.95)
    w = jnp.asarray([[1.0, -2.0], [0.5, 3.0]])
    params = {"up": w}
    state = opt.adamw_init(params)
    wn = np.asarray(w, np.float64)
    mu = np.zeros_like(wn)
    nu = np.zeros_like(wn)
    for t in range(1, 6):
        g = {"up": jnp.asarray(np.full((2, 2), 0.1 * t, np.float32))}
        params, state = opt.adamw_update(g, state, params, lr=1e-2, cfg=cfg)
        gn = np.full((2, 2), 0.1 * t)
        mu = 0.9 * mu + 0.1 * gn
        nu = 0.95 * nu + 0.05 * gn ** 2
        mh = mu / (1 - 0.9 ** t)
        nh = nu / (1 - 0.95 ** t)
        wn = wn - 1e-2 * (mh / (np.sqrt(nh) + 1e-8) + 0.1 * wn)
    np.testing.assert_allclose(np.asarray(params["up"]), wn, atol=1e-5)


def test_no_weight_decay_on_norms():
    cfg = TrainConfig(learning_rate=0.0, weight_decay=1.0)
    params = {"norm": jnp.ones((4,)), "up": jnp.ones((4,))}
    state = opt.adamw_init(params)
    g = jax.tree.map(jnp.zeros_like, params)
    p2, _ = opt.adamw_update(g, state, params, lr=0.0, cfg=cfg)
    # lr=0: nothing moves regardless; use lr>0 to see decay only on "up"
    p3, _ = opt.adamw_update(g, opt.adamw_init(params), params, lr=0.1,
                             cfg=cfg)
    assert jnp.allclose(p3["norm"], params["norm"])
    assert not jnp.allclose(p3["up"], params["up"])


def test_lr_schedule_shape():
    cfg = TrainConfig(learning_rate=1e-3, min_lr=1e-4, warmup_steps=10,
                      total_steps=100)
    lr = opt.lr_schedule(cfg)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0, abs=1e-9)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(jnp.asarray(55))) < 1e-3


@pytest.mark.parametrize("mode", ["standard", "ladder"])
def test_loss_decreases(mode):
    """~100 steps on structured synthetic data: loss must drop clearly."""
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=128
    ).replace(residual_mode=ResidualMode(mode))
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=10, total_steps=120,
                       weight_decay=0.0)
    loader = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                         global_batch=8, seed=0)
    params = tfm.init_params(cfg, jax.random.key(0))
    state = opt.adamw_init(params)
    lr_fn = opt.lr_schedule(tcfg)

    @jax.jit
    def step(params, state, batch, i):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tpmod.lm_loss(cfg, p, batch, NULL_ENV, tcfg, True),
            has_aux=True)(params)
        grads, _ = opt.clip_by_global_norm(grads, tcfg.grad_clip)
        params, state = opt.adamw_update(grads, state, params,
                                         lr=lr_fn(i), cfg=tcfg)
        return params, state, loss

    losses = []
    for i in range(120):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(i).items()}
        params, state, loss = step(params, state, batch,
                                   jnp.asarray(i, jnp.int32))
        losses.append(float(loss))
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.25, (first, last)


def test_checkpoint_restart_bitwise():
    """Train 6 steps; vs train 3, checkpoint, restore, train 3 — identical
    parameters (the loader being a pure function of step makes this hold)."""
    cfg = REGISTRY["stablelm-3b"].reduced(n_layers=2, d_model=32,
                                          n_heads=2, d_ff=64, vocab_size=64)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10,
                       weight_decay=0.01)
    loader = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                         global_batch=4, seed=1)
    lr_fn = opt.lr_schedule(tcfg)

    @jax.jit
    def step(params, state, batch, i):
        (_, _), grads = jax.value_and_grad(
            lambda p: tpmod.lm_loss(cfg, p, batch, NULL_ENV, tcfg, True),
            has_aux=True)(params)
        return opt.adamw_update(grads, state, params, lr=lr_fn(i), cfg=tcfg)

    def run(n0, params, state):
        for i in range(n0, n0 + 3):
            batch = {k: jnp.asarray(v)
                     for k, v in loader.batch_at(i).items()}
            params, state = step(params, state, batch,
                                 jnp.asarray(i, jnp.int32))
        return params, state

    p0 = tfm.init_params(cfg, jax.random.key(0))
    s0 = opt.adamw_init(p0)

    pa, sa = run(0, p0, s0)
    pa, sa = run(3, pa, sa)

    pb, sb = run(0, p0, s0)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(3, pb, sb)
        step_r, pc, sc, _ = mgr.restore(pb, sb)
        assert step_r == 3
        pc, sc = run(3, pc, sc)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        p = {"w": jnp.ones((2,))}
        for s in [1, 2, 3, 4]:
            mgr.save(s, p)
        assert mgr.steps() == [3, 4]
        assert not list(os.scandir(os.path.join(d))) == []
        # tmp dirs never survive
        assert not [f for f in os.listdir(d) if f.startswith("tmp-")]


def test_data_determinism_and_shardability():
    ld = SyntheticLM(vocab_size=100, seq_len=8, global_batch=4, seed=7)
    a = ld.batch_at(5)
    b = ld.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ld.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])


def test_fault_tolerance_units():
    import time as _time
    from repro.training.fault_tolerance import (FTConfig, FleetController,
                                                Heartbeat, RestartBudget,
                                                StragglerMonitor)
    with tempfile.TemporaryDirectory() as d:
        hb0 = Heartbeat(d, "h0")
        hb1 = Heartbeat(d, "h1")
        hb0.beat(1)
        hb1.beat(1)
        now = _time.time()
        alive = Heartbeat.scan(d, dead_after_s=60, now=now)
        assert alive == {"h0": True, "h1": True}
        alive = Heartbeat.scan(d, dead_after_s=0.0, now=now + 10)
        assert alive == {"h0": False, "h1": False}

        mon = StragglerMonitor(FTConfig(patience=2, straggler_factor=1.5))
        for _ in range(4):
            mon.observe("h0", 1.0)
            mon.observe("h1", 1.0)
            mon.observe("h2", 10.0)
            mon.flagged()
        assert "h2" in mon.flagged()

        hb0.beat(2)
        hb1.beat(2)
        fc = FleetController(FTConfig(policy="exclude"),
                             hosts=["h0", "h1", "h2"], chips_per_host=8)
        plan = fc.plan_restart(d, stragglers=["h1"])
        assert plan["survivors"] == ["h0"]
        assert plan["world"] == 8
        assert "h2" in plan["lost"]

        rb = RestartBudget(FTConfig(max_restarts=2, window_s=100))
        t0 = 1000.0
        assert rb.allow(t0) and rb.allow(t0 + 1)
        assert not rb.allow(t0 + 2)
        assert rb.allow(t0 + 200)  # window expired


def test_elastic_checkpoint_resharding():
    """Save under one layout, restore into protos of another world size —
    full-array checkpoints are mesh-independent by construction."""
    cfg = REGISTRY["stablelm-3b"].reduced(n_layers=2, d_model=32,
                                          n_heads=2, d_ff=64, vocab_size=64)
    params = tfm.init_params(cfg, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, params)
        _, restored, _, _ = mgr.restore(params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
