"""Post-training adaptation (paper §4.2): convert the upper half of a
trained STANDARD transformer to Ladder Residual, measure the zero-shot
degradation, then recover it with brief fine-tuning.

The conversion itself is free — Ladder Residual reuses the exact same
parameters and only rewires the residual stream (cfg.replace) — which is
why the paper's 3B-token adaptation is so light.

    PYTHONPATH=src python examples/adapt_hybrid_ladder.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, ResidualMode, TrainConfig
from repro.models import transformer as tfm
from repro.parallel import tp as tpmod
from repro.parallel.collectives import NULL_ENV
from repro.training import optimizer as opt
from repro.training.data import SyntheticLM


def eval_loss(cfg, params, loader, steps=8):
    tot = 0.0
    for i in range(1000, 1000 + steps):  # held-out step range
        b = {k: jnp.asarray(v) for k, v in loader.batch_at(i).items()}
        tot += float(tpmod.lm_loss(cfg, params, b, NULL_ENV,
                                   TrainConfig(), train=False)[0])
    return tot / steps


def train(cfg, params, loader, steps, lr0=2e-3, start=0):
    tcfg = TrainConfig(learning_rate=lr0, warmup_steps=10,
                       total_steps=steps, weight_decay=0.01)
    state = opt.adamw_init(params)
    lr = opt.lr_schedule(tcfg)

    @jax.jit
    def step(params, state, b, i):
        (loss, _), g = jax.value_and_grad(
            lambda p: tpmod.lm_loss(cfg, p, b, NULL_ENV, tcfg, True),
            has_aux=True)(params)
        g, _ = opt.clip_by_global_norm(g, 1.0)
        return *opt.adamw_update(g, state, params, lr=lr(i), cfg=tcfg), loss

    for i in range(steps):
        b = {k: jnp.asarray(v)
             for k, v in loader.batch_at(start + i).items()}
        params, state, loss = step(params, state, b,
                                   jnp.asarray(i, jnp.int32))
    return params


def main():
    base = REGISTRY["ladder-1b"].reduced(
        n_layers=8, d_model=256, n_heads=8, d_ff=1024, vocab_size=4096)
    loader = SyntheticLM(vocab_size=base.vocab_size, seq_len=128,
                         global_batch=8)

    # 1. pretrain a STANDARD transformer
    std = base.replace(residual_mode=ResidualMode.STANDARD)
    params = tfm.init_params(std, jax.random.key(0))
    params = train(std, params, loader, steps=250)
    l_std = eval_loss(std, params, loader)
    print(f"standard pretrained           eval loss {l_std:.4f}")

    # 2. rewire the upper half to Ladder — SAME parameters, zero-shot
    hybrid = base.replace(residual_mode=ResidualMode.LADDER,
                          ladder_start_layer=4)
    l_zero = eval_loss(hybrid, params, loader)
    print(f"hybrid-ladder zero-shot       eval loss {l_zero:.4f} "
          f"(degradation {l_zero - l_std:+.4f})  <- paper Table 4 row 2")

    # 3. brief recovery fine-tune (the paper's 3B-token SFT analogue)
    params_ft = train(hybrid, params, loader, steps=120, lr0=5e-4,
                      start=300)
    l_ft = eval_loss(hybrid, params_ft, loader)
    print(f"hybrid-ladder retrained       eval loss {l_ft:.4f} "
          f"(recovered {l_zero - l_ft:.4f})      <- paper Table 4 row 3")

    if l_zero <= l_std:
        print("note: no zero-shot degradation at this toy scale (the paper's"
              " 8B shows a large generative-task drop; tiny models on"
              " synthetic data can be insensitive to the rewiring)")
    print("OK" if l_ft <= l_zero else "WARN: recovery incomplete")


if __name__ == "__main__":
    main()
