"""Batched serving example: prefill a batch of prompts, decode with the
KV-cache engine, compare Standard vs Ladder step latency structure.

On CPU at TP=1 there is no communication to overlap — the point of this
example is the END-TO-END serving path (cache build, prefill, decode loop,
greedy sampling) through the public API.  The modeled TP-8/TP-16 latencies
come from core/schedule.py (printed at the end).

    PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, ParallelConfig, ResidualMode
from repro.core import schedule as sched
from repro.models import transformer as tfm
from repro.parallel.collectives import NULL_ENV
from repro.serving import engine, sampler


def main():
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=4, d_model=256, n_heads=8, d_ff=1024, vocab_size=4096
    ).replace(residual_mode=ResidualMode.LADDER)
    params = tfm.init_params(cfg, jax.random.key(0))
    pcfg = ParallelConfig()

    b, prompt_len, gen = 4, 64, 24
    s_max = prompt_len + gen
    prompts = jax.random.randint(jax.random.key(1), (b, prompt_len), 0,
                                 cfg.vocab_size)

    caches, _ = engine.build_caches(cfg, b, s_max, pcfg, for_decode=False)

    @jax.jit
    def prefill(params, tokens, caches):
        hidden, caches, _ = tfm.forward(cfg, params, tokens, NULL_ENV,
                                        caches=caches)
        tok = sampler.greedy(
            tfm.logits_shard(cfg, params, hidden[:, -1:])[:, 0], NULL_ENV,
            cfg.vocab_size)
        return caches, tok

    @jax.jit
    def decode(params, tok, caches, pos):
        positions = jnp.full((b, 1), pos, jnp.int32)
        hidden, caches, _ = tfm.forward(cfg, params, tok[:, None], NULL_ENV,
                                        positions=positions, caches=caches,
                                        unroll=True)
        tok = sampler.greedy(tfm.logits_shard(cfg, params, hidden)[:, 0],
                             NULL_ENV, cfg.vocab_size)
        return caches, tok

    t0 = time.time()
    caches, tok = prefill(params, prompts, caches)
    tok.block_until_ready()
    t_pref = time.time() - t0

    seqs = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        caches, tok = decode(params, tok, caches,
                             jnp.asarray(prompt_len + i, jnp.int32))
        seqs.append(tok)
    tok.block_until_ready()
    t_dec = time.time() - t0

    out = jnp.stack(seqs, 1)
    print(f"prefill {prompt_len}x{b} tokens: {t_pref*1e3:.1f} ms")
    print(f"decode  {gen-1} steps:          {t_dec*1e3:.1f} ms "
          f"({(gen-1)*b/t_dec:.0f} tok/s on 1 CPU core)")
    print(f"sample continuation ids: {out[0, :12].tolist()}")

    # modeled production latency (stablelm-3b full config, TP16 on v5e)
    full = REGISTRY["stablelm-3b"]
    rows = sched.speedup_table(full, tp=16, batch=8, prompt=1024, gen=512,
                               hw=sched.TPU_V5E)
    print("\nmodeled on TPU v5e TP=16 (full 3B config, 1024+512, batch 8):")
    for m in ["standard", "parallel", "ladder", "no_comm"]:
        r = rows[m]
        print(f"  {m:9s}: {r['tok_per_s']:8.0f} tok/s  x{r['speedup']:.2f}")


if __name__ == "__main__":
    main()
