"""Paged-KV serving example: variable-length prompts sharing a system prefix
arrive over time, are admitted block-by-block into a physical KV pool, decode
as ONE mixed-age batch, and retire independently — through the public engine
API (DESIGN.md §Paged KV).

On CPU at TP=1 there is no communication to overlap — the point of this
example is the END-TO-END serving path: hash-chained prefix reuse (every
request after the first gets its system-prompt K/V for free), chunked
prefill interleaving with decode, block-granular admission, per-request
sampling.  The modeled TP-8/TP-16 latencies come from core/schedule.py
(printed at the end).

    PYTHONPATH=src python examples/serve_batched.py

``--use-pallas`` reads the KV pool through the block-table-native Pallas
paged-attention kernel instead of the gather path — same tokens, bytes-read
scaling with each row's actual kv length (DESIGN.md §Paged-attention
kernel); interpret mode on CPU, so it is slower here and faster on TPU.

``--kv-int8`` demonstrates the KV memory tiers (DESIGN.md §KV memory
tiers): the pool is stored int8 with per-(token, head) scales inside the
SAME byte budget the fp default uses — which fits ~3.5x the blocks — and
TWICE the requests are served with admission oversubscribed and the
preemptive scheduler swapping rows through the host tier under pressure.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import REGISTRY, ResidualMode
from repro.core import schedule as sched
from repro.models import transformer as tfm
from repro.serving.scheduler import (PagedServingEngine, Request,
                                     SamplingParams)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--use-pallas", action="store_true",
                    help="paged attention via the Pallas kernel "
                         "(bit-identical tokens; interpret mode on CPU)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="store the KV pool int8 inside the fp default's "
                         "byte budget, oversubscribe admission, and serve "
                         "2x the requests through the preemptive memory "
                         "tier (DESIGN.md §KV memory tiers)")
    args = ap.parse_args()

    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=4, d_model=256, n_heads=8, d_ff=1024, vocab_size=4096
    ).replace(residual_mode=ResidualMode.LADDER)
    params = tfm.init_params(cfg, jax.random.key(0))

    from repro.serving.kv_cache import kv_block_bytes

    rng = np.random.default_rng(1)
    bs, s_max, slots = 8, 96, 3
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    esize = np.dtype(cfg.dtype).itemsize
    fp_blocks = slots * (s_max // bs)           # the fp default pool
    fp_block_bytes = kv_block_bytes(bs, hkv, hd, esize)
    i8_block_bytes = kv_block_bytes(bs, hkv, hd, esize, "int8")
    mem_kw = {}
    if args.kv_int8:
        # same byte budget, int8 layout -> ~3.5x the blocks; oversubscribe
        # so admission uses them eagerly and preemption handles the rest
        mem_kw = dict(kv_quant="int8",
                      num_blocks=fp_blocks * fp_block_bytes
                      // i8_block_bytes,
                      oversubscribe=2.0)
    engine = PagedServingEngine(cfg, params, batch_slots=slots, s_max=s_max,
                                block_size=bs, max_prefill_tokens=32,
                                use_pallas=args.use_pallas or None,
                                **mem_kw)
    pool_mb = engine.num_blocks * (i8_block_bytes if args.kv_int8
                                   else fp_block_bytes) / 1e6
    print(f"KV pool: {engine.num_blocks} blocks "
          f"({'int8' if args.kv_int8 else 'fp32'}, {pool_mb:.2f} MB/layer; "
          f"fp default is {fp_blocks} blocks, "
          f"{fp_blocks * fp_block_bytes / 1e6:.2f} MB/layer)")

    # 6 requests (12 with --kv-int8: same byte budget, twice the load)
    # behind ONE shared 32-token system prompt (4 full blocks at
    # block_size=8): request 0 prefills it once, every later admission hits
    # the prefix cache and allocates fresh blocks only for its own tail.
    system = rng.integers(0, cfg.vocab_size, 32).tolist()
    shapes = [(9, 12), (33, 8), (17, 16), (50, 10), (5, 20), (24, 6)]
    if args.kv_int8:
        shapes = shapes + [(lp + 3, gen) for lp, gen in shapes]
    requests = []
    for rid, (lp, gen) in enumerate(shapes):
        samp = SamplingParams() if rid % 2 == 0 else \
            SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=rid)
        requests.append(Request(
            rid=rid,
            prompt=system + rng.integers(0, cfg.vocab_size, lp).tolist(),
            max_new_tokens=gen, sampling=samp))

    # stagger arrivals: two up front, the rest submitted mid-flight
    engine.submit(requests[0])
    engine.submit(requests[1])
    t0 = time.time()
    steps = 0
    next_arrival = 2
    while engine.has_work() or next_arrival < len(requests):
        if next_arrival < len(requests) and steps % 2 == 0:
            engine.submit(requests[next_arrival])
            next_arrival += 1
        engine.step()
        steps += 1
    wall = time.time() - t0

    finished = {f.rid: f for f in engine.scheduler.finished}
    n_tok = sum(len(f.tokens) for f in finished.values())
    st = engine.stats()
    print(f"served {len(finished)} ragged requests on 3 slots in {steps} "
          f"engine steps: {n_tok} tokens, {wall:.2f}s "
          f"({n_tok / max(wall, 1e-9):.0f} tok/s on 1 CPU core)")
    print(f"paged KV: prefix_hit_rate={st['prefix_hit_rate']:.2f} "
          f"({st['prefix_hit_tokens']} of "
          f"{st['prefix_hit_tokens'] + st['prefill_tokens']} prompt tokens "
          f"reused), block_util peak={st['block_util_peak']:.2f}")
    if "preemptions" in st:
        print(f"memory tier: preemptions={st['preemptions']} "
              f"resumes={st['resumes']} "
              f"swapped_out={st['swapped_out_blocks']} blocks "
              f"(oversubscribe x{st['oversubscribe']:.1f})")
    for rid in sorted(finished):
        f = finished[rid]
        rs = engine.scheduler.request_stats[rid]
        kind = "greedy " if rid % 2 == 0 else "sampled"
        print(f"  rid={rid} {kind} prompt={len(f.prompt):2d} "
              f"(cached {rs['cached_tokens']:2d}, "
              f"{rs['fresh_blocks']} fresh blocks) "
              f"-> {len(f.tokens):2d} toks ({f.finish_reason}): "
              f"{f.tokens[:8]}")

    # modeled production latency (stablelm-3b full config, TP16 on v5e)
    full = REGISTRY["stablelm-3b"]
    rows = sched.speedup_table(full, tp=16, batch=8, prompt=1024, gen=512,
                               hw=sched.TPU_V5E)
    print("\nmodeled on TPU v5e TP=16 (full 3B config, 1024+512, batch 8):")
    for m in ["standard", "parallel", "ladder", "no_comm"]:
        r = rows[m]
        print(f"  {m:9s}: {r['tok_per_s']:8.0f} tok/s  x{r['speedup']:.2f}")


if __name__ == "__main__":
    main()
