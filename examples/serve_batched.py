"""Paged-KV serving example: variable-length prompts sharing a system prefix
arrive over time, are admitted block-by-block into a physical KV pool, decode
as ONE mixed-age batch, and retire independently — through the public engine
API (DESIGN.md §Paged KV).

On CPU at TP=1 there is no communication to overlap — the point of this
example is the END-TO-END serving path: hash-chained prefix reuse (every
request after the first gets its system-prompt K/V for free), chunked
prefill interleaving with decode, block-granular admission, per-request
sampling.  The modeled TP-8/TP-16 latencies come from core/schedule.py
(printed at the end).

    PYTHONPATH=src python examples/serve_batched.py

``--use-pallas`` reads the KV pool through the block-table-native Pallas
paged-attention kernel instead of the gather path — same tokens, bytes-read
scaling with each row's actual kv length (DESIGN.md §Paged-attention
kernel); interpret mode on CPU, so it is slower here and faster on TPU.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import REGISTRY, ResidualMode
from repro.core import schedule as sched
from repro.models import transformer as tfm
from repro.serving.scheduler import (PagedServingEngine, Request,
                                     SamplingParams)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--use-pallas", action="store_true",
                    help="paged attention via the Pallas kernel "
                         "(bit-identical tokens; interpret mode on CPU)")
    args = ap.parse_args()

    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=4, d_model=256, n_heads=8, d_ff=1024, vocab_size=4096
    ).replace(residual_mode=ResidualMode.LADDER)
    params = tfm.init_params(cfg, jax.random.key(0))

    rng = np.random.default_rng(1)
    engine = PagedServingEngine(cfg, params, batch_slots=3, s_max=96,
                                block_size=8, max_prefill_tokens=32,
                                use_pallas=args.use_pallas or None)

    # 6 requests behind ONE shared 32-token system prompt (4 full blocks at
    # block_size=8): request 0 prefills it once, every later admission hits
    # the prefix cache and allocates fresh blocks only for its own tail.
    system = rng.integers(0, cfg.vocab_size, 32).tolist()
    requests = []
    for rid, (lp, gen) in enumerate([(9, 12), (33, 8), (17, 16),
                                     (50, 10), (5, 20), (24, 6)]):
        samp = SamplingParams() if rid % 2 == 0 else \
            SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=rid)
        requests.append(Request(
            rid=rid,
            prompt=system + rng.integers(0, cfg.vocab_size, lp).tolist(),
            max_new_tokens=gen, sampling=samp))

    # stagger arrivals: two up front, the rest submitted mid-flight
    engine.submit(requests[0])
    engine.submit(requests[1])
    t0 = time.time()
    steps = 0
    next_arrival = 2
    while engine.has_work() or next_arrival < len(requests):
        if next_arrival < len(requests) and steps % 2 == 0:
            engine.submit(requests[next_arrival])
            next_arrival += 1
        engine.step()
        steps += 1
    wall = time.time() - t0

    finished = {f.rid: f for f in engine.scheduler.finished}
    n_tok = sum(len(f.tokens) for f in finished.values())
    st = engine.stats()
    print(f"served {len(finished)} ragged requests on 3 slots in {steps} "
          f"engine steps: {n_tok} tokens, {wall:.2f}s "
          f"({n_tok / max(wall, 1e-9):.0f} tok/s on 1 CPU core)")
    print(f"paged KV: prefix_hit_rate={st['prefix_hit_rate']:.2f} "
          f"({st['prefix_hit_tokens']} of "
          f"{st['prefix_hit_tokens'] + st['prefill_tokens']} prompt tokens "
          f"reused), block_util peak={st['block_util_peak']:.2f}")
    for rid in sorted(finished):
        f = finished[rid]
        rs = engine.scheduler.request_stats[rid]
        kind = "greedy " if rid % 2 == 0 else "sampled"
        print(f"  rid={rid} {kind} prompt={len(f.prompt):2d} "
              f"(cached {rs['cached_tokens']:2d}, "
              f"{rs['fresh_blocks']} fresh blocks) "
              f"-> {len(f.tokens):2d} toks ({f.finish_reason}): "
              f"{f.tokens[:8]}")

    # modeled production latency (stablelm-3b full config, TP16 on v5e)
    full = REGISTRY["stablelm-3b"]
    rows = sched.speedup_table(full, tp=16, batch=8, prompt=1024, gen=512,
                               hw=sched.TPU_V5E)
    print("\nmodeled on TPU v5e TP=16 (full 3B config, 1024+512, batch 8):")
    for m in ["standard", "parallel", "ladder", "no_comm"]:
        r = rows[m]
        print(f"  {m:9s}: {r['tok_per_s']:8.0f} tok/s  x{r['speedup']:.2f}")


if __name__ == "__main__":
    main()
