"""End-to-end pretraining driver: Standard vs Ladder vs Parallel Transformer
from scratch on the same data — the paper's §4.1 experiment at toy scale.

Default: three ~12M-param models, 200 steps each, loss curves printed side
by side (expected: ladder ≈ standard ≈ parallel, mirroring Table 3).

    PYTHONPATH=src python examples/train_ladder_lm.py [--steps 200]
    PYTHONPATH=src python examples/train_ladder_lm.py --full-100m  # ~100M

With --tp/--dp/--devices this drives the sharded Trainer (checkpoints,
heartbeats, ZeRO-1/FSDP) instead of the single-device loop.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--modes", default="standard,ladder,parallel")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.devices:
        import os
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    import jax
    import jax.numpy as jnp
    from repro.configs import (REGISTRY, ParallelConfig, ResidualMode,
                               TrainConfig)
    from repro.parallel import tp as tpmod
    from repro.parallel.collectives import NULL_ENV
    from repro.training import optimizer as opt
    from repro.training.data import SyntheticLM

    if args.full_100m:
        base = REGISTRY["ladder-1b"].reduced(
            n_layers=12, d_model=768, n_heads=12, d_ff=2048,
            vocab_size=32768)
        seq, batch = 512, 8
    else:
        base = REGISTRY["ladder-1b"].reduced(
            n_layers=6, d_model=256, n_heads=8, d_ff=1024, vocab_size=4096)
        seq, batch = 128, 8

    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                       total_steps=args.steps, weight_decay=0.01)
    loader = SyntheticLM(vocab_size=base.vocab_size, seq_len=seq,
                         global_batch=batch)

    results = {}
    for mode in args.modes.split(","):
        cfg = base.replace(residual_mode=ResidualMode(mode))
        if args.tp * args.dp > 1:
            from repro.launch.mesh import make_mesh_for
            from repro.training.trainer import Trainer
            pcfg = ParallelConfig(tp=args.tp, dp=args.dp)
            mesh = make_mesh_for(pcfg.world, args.tp)
            tr = Trainer(cfg, mesh, pcfg, tcfg, ckpt_dir=args.ckpt)
            losses = []
            tr.fit(tr.resume_or_init(), loader, args.steps,
                   on_metrics=lambda s, m: losses.append(m["loss"]))
        else:
            from repro.models import transformer as tfm
            params = tfm.init_params(cfg, jax.random.key(0))
            state = opt.adamw_init(params)
            lr = opt.lr_schedule(tcfg)

            @jax.jit
            def step(params, state, b, i):
                (loss, _), g = jax.value_and_grad(
                    lambda p: tpmod.lm_loss(cfg, p, b, NULL_ENV, tcfg,
                                            True), has_aux=True)(params)
                g, _ = opt.clip_by_global_norm(g, tcfg.grad_clip)
                params, state = opt.adamw_update(g, state, params,
                                                 lr=lr(i), cfg=tcfg)
                return params, state, loss

            losses = []
            for i in range(args.steps):
                b = {k: jnp.asarray(v)
                     for k, v in loader.batch_at(i).items()}
                params, state, loss = step(params, state, b,
                                           jnp.asarray(i, jnp.int32))
                losses.append(float(loss))
                if i % 50 == 0:
                    print(f"[{mode:9s}] step {i:4d} loss {losses[-1]:.3f}")
        results[mode] = losses

    print("\n=== final losses (mean of last 10 steps) — paper §4.1 analogue")
    for mode, losses in results.items():
        import numpy as np
        print(f"  {mode:9s}: {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
