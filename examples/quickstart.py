"""Quickstart: build a small Ladder Transformer, train a few steps, generate.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, ResidualMode, TrainConfig
from repro.models import transformer as tfm
from repro.models.model import build_model
from repro.parallel import tp as tpmod
from repro.parallel.collectives import NULL_ENV
from repro.serving import engine
from repro.training import optimizer as opt
from repro.training.data import SyntheticLM
from repro.configs import ParallelConfig


def main():
    # a ~5M-param Ladder Transformer (the paper's architecture knob is just
    # `residual_mode`; every zoo architecture accepts it)
    cfg = REGISTRY["stablelm-3b"].reduced(
        n_layers=4, d_model=128, n_heads=4, d_ff=512, vocab_size=512
    ).replace(residual_mode=ResidualMode.LADDER)
    print(f"model: {cfg.name} / {cfg.residual_mode.value}")

    init, apply = build_model(cfg)
    params = init(jax.random.key(0))

    # --- a few training steps --------------------------------------------
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60)
    loader = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=8)
    state = opt.adamw_init(params)
    lr = opt.lr_schedule(tcfg)

    @jax.jit
    def step(params, state, batch, i):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tpmod.lm_loss(cfg, p, batch, NULL_ENV, tcfg, True),
            has_aux=True)(params)
        grads, _ = opt.clip_by_global_norm(grads, 1.0)
        params, state = opt.adamw_update(grads, state, params, lr=lr(i),
                                         cfg=tcfg)
        return params, state, loss

    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(i).items()}
        params, state, loss = step(params, state, batch,
                                   jnp.asarray(i, jnp.int32))
        if i % 20 == 0:
            print(f"step {i:3d} loss {float(loss):.3f}")
    print(f"final loss {float(loss):.3f}")

    # --- greedy generation through the KV-cache engine --------------------
    pcfg = ParallelConfig()
    prompt = jnp.asarray(loader.batch_at(999)["tokens"][:2, :16])
    caches, _ = engine.build_caches(cfg, 2, 32, pcfg, for_decode=False)
    hidden, caches, _ = tfm.forward(cfg, params, prompt, NULL_ENV,
                                    caches=caches)
    from repro.serving import sampler
    tok = sampler.greedy(tfm.logits_shard(cfg, params, hidden[:, -1:])[:, 0],
                         NULL_ENV, cfg.vocab_size)
    out = [int(tok[0])]
    for i in range(8):
        pos = jnp.full((2, 1), 16 + i, jnp.int32)
        hidden, caches, _ = tfm.forward(cfg, params, tok[:, None], NULL_ENV,
                                        positions=pos, caches=caches,
                                        unroll=True)
        tok = sampler.greedy(
            tfm.logits_shard(cfg, params, hidden)[:, 0], NULL_ENV,
            cfg.vocab_size)
        out.append(int(tok[0]))
    print("generated ids:", out)


if __name__ == "__main__":
    main()
