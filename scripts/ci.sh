#!/usr/bin/env bash
# Canonical pre-merge check: the FAST test tier (see pyproject.toml and
# tests/conftest.py).  Single-process tests only — the multi-device
# subprocess suites are `slow`-marked and run in the full tier:
#
#   scripts/ci.sh            # fast tier (pre-merge gate)
#   scripts/ci.sh --full     # fast + slow (everything)
#
# Extra args are forwarded to pytest, e.g. `scripts/ci.sh -k scheduler`.
set -euo pipefail
cd "$(dirname "$0")/.."

MARK=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
    MARK=()
    shift
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q "${MARK[@]}" "$@"
