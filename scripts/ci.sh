#!/usr/bin/env bash
# Canonical pre-merge check: the FAST test tier (see pyproject.toml and
# tests/conftest.py).  Single-process tests only — the multi-device
# subprocess suites are `slow`-marked and run in the full tier:
#
#   scripts/ci.sh            # fast tier (pre-merge gate)
#   scripts/ci.sh --full     # fast + slow (everything)
#   scripts/ci.sh --lint     # ruff lint + format ratchet (no tests)
#   scripts/ci.sh --docs     # smoke-run README quickstart code blocks
#
# Extra args are forwarded to pytest, e.g. `scripts/ci.sh -k scheduler`.
# .github/workflows/ci.yml runs the fast tier on every push/PR (two jax
# versions), --lint and --docs alongside it, and --full + the serve-bench
# regression gate (scripts/check_bench.py) nightly.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--lint" ]]; then
    shift
    python -m ruff check .
    # Format ratchet: files added since the CI pipeline landed are held to
    # `ruff format`; extend this list as older files get reformatted.
    python -m ruff format --check \
        scripts/check_bench.py tests/test_paged.py tests/test_ci_pipeline.py \
        src/repro/kernels/paged_attention.py tests/test_paged_kernel.py \
        benchmarks/kernel_bench.py \
        src/repro/serving/memory.py src/repro/quant.py tests/test_memory.py \
        src/repro/parallel/overlap.py src/repro/kernels/comm.py \
        tests/test_collectives.py benchmarks/comm_bench.py \
        src/repro/kernels/autotune.py tests/test_autotune.py \
        benchmarks/bench_io.py
    exit 0
fi

if [[ "${1:-}" == "--docs" ]]; then
    shift
    python scripts/check_docs.py "$@"
    exit 0
fi

MARK=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
    MARK=()
    shift
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q "${MARK[@]}" "$@"
