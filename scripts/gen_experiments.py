"""Regenerate EXPERIMENTS.md tables from results/dryrun.json.

    python scripts/gen_experiments.py > EXPERIMENTS.md
"""

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
rows = json.loads((ROOT / "results" / "dryrun.json").read_text())
by_cell = {r["cell"]: r for r in rows}


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def table(mesh, tag_suffix=""):
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck "
           "| useful | mem/dev GB | fit |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: r.get("cell", "")):
        cell = r.get("cell", "")
        if f"/{mesh}/" not in cell or r.get("status") != "ok":
            continue
        if not cell.endswith("/ladder" + tag_suffix):
            continue
        arch, shape = cell.split("/")[:2]
        mem = (r["mem"]["argument"] + max(
            r["mem"]["temp"] - r["mem"].get("output", 0), 0)) / 1e9
        tmem = r.get("t_memory_nocopy", r["t_memory"])
        fit = "Y" if mem <= 16.0 else f"OVER({mem:.0f})"
        out.append(
            f"| {arch} | {shape} | {fmt_ms(r['t_compute'])} | "
            f"{fmt_ms(tmem)} | {fmt_ms(r['t_collective'])} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | {mem:.1f} | "
            f"{fit} |")
    return "\n".join(out)


def skips(mesh):
    out = []
    for r in rows:
        if r.get("status") == "skipped" and f"/{mesh}/" in r["cell"]:
            out.append("- " + r["cell"].split("/" + mesh)[0])
    return "\n".join(sorted(set(out)))


def cellrow(cell):
    r = by_cell.get(cell)
    if not r or r.get("status") != "ok":
        return None
    return r


HEADER = (ROOT / "scripts" / "experiments_header.md").read_text()
print(HEADER)

print("\n### Single-pod (16x16 = 256 chips) baseline — all 40 cells\n")
print(table("16x16"))
print("\nSkipped (documented in DESIGN.md §Arch-applicability — long_500k "
      "needs sub-quadratic attention):\n")
print(skips("16x16"))
print("\n### Multi-pod (2x16x16 = 512 chips) — all 40 cells\n")
print(table("2x16x16"))
print("\nSkips mirror the single-pod set.\n")

print((ROOT / "scripts" / "experiments_footer.md").read_text())
