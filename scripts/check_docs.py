#!/usr/bin/env python
"""Docs smoke-runner: keep the README quickstart executable.

Extracts fenced ```bash code blocks from README.md (and any other files
passed on the command line) and executes each one with `bash -euo pipefail`
from the repo root, PYTHONPATH=src preset.  A block whose text contains a
line starting with `# docs: skip` is listed but not executed — use it for
blocks that are slow (the full test tier), need network (pip install), or
duplicate another CI job.

This is the `scripts/ci.sh --docs` gate (DESIGN.md §Bench/CI): if a
README command rots — a renamed flag, a moved module, a deleted entry
point — the docs job fails instead of the next reader.

Usage:
    python scripts/check_docs.py                 # README.md + docs/serving.md
    python scripts/check_docs.py --list          # show blocks + skip status
    python scripts/check_docs.py docs/foo.md     # specific files only
"""

import argparse
import os
import re
import subprocess
import sys
import time
from dataclasses import dataclass
from pathlib import Path
from typing import List

ROOT = Path(__file__).resolve().parents[1]

# ```bash ... ``` fences; the info string must be exactly `bash` (other
# languages and plain fences are documentation, not executable contract)
_FENCE = re.compile(r"^```bash[ \t]*\n(.*?)^```[ \t]*$",
                    re.MULTILINE | re.DOTALL)
_SKIP = re.compile(r"^\s*#\s*docs:\s*skip", re.MULTILINE)


@dataclass
class Block:
    source: str     # file the block came from
    index: int      # 1-based position among that file's bash blocks
    text: str

    @property
    def skipped(self) -> bool:
        return bool(_SKIP.search(self.text))

    @property
    def title(self) -> str:
        first = next((ln for ln in self.text.splitlines()
                      if ln.strip() and not ln.lstrip().startswith("#")),
                     "(comment-only block)")
        return f"{self.source}#{self.index}: {first.strip()}"


def extract_blocks(path: Path) -> List[Block]:
    text = path.read_text()
    rel = str(path.relative_to(ROOT)) if path.is_relative_to(ROOT) \
        else str(path)
    return [Block(source=rel, index=i + 1, text=m)
            for i, m in enumerate(_FENCE.findall(text))]


def run_block(block: Block, timeout: float) -> bool:
    t0 = time.monotonic()
    try:
        proc = subprocess.run(
            ["bash", "-euo", "pipefail", "-c", block.text],
            cwd=ROOT, capture_output=True, text=True, timeout=timeout,
            env={**os.environ, "PYTHONPATH": str(ROOT / "src")})
    except subprocess.TimeoutExpired:
        # a hung block is a FAIL, not a crash: report it and keep checking
        # the remaining blocks so the summary stays complete
        print(f"FAIL [{time.monotonic() - t0:5.1f}s] {block.title} "
              f"(timed out after {timeout:.0f}s)")
        return False
    dt = time.monotonic() - t0
    ok = proc.returncode == 0
    print(f"{'ok  ' if ok else 'FAIL'} [{dt:5.1f}s] {block.title}")
    if not ok:
        sys.stdout.write(proc.stdout[-2000:])
        sys.stdout.write(proc.stderr[-2000:])
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=[],
                    help="markdown files to check (default: README.md "
                         "and docs/serving.md)")
    ap.add_argument("--list", action="store_true",
                    help="list extracted blocks without running them")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-block timeout in seconds")
    args = ap.parse_args(argv)

    files = [Path(f) for f in args.files] or \
        [ROOT / "README.md", ROOT / "docs" / "serving.md"]
    blocks: List[Block] = []
    for f in files:
        if not f.exists():
            print(f"FAIL {f}: no such file")
            return 1
        blocks.extend(extract_blocks(f))
    if not blocks:
        print(f"FAIL: no ```bash blocks found in {', '.join(map(str, files))}"
              " (quickstart gone missing?)")
        return 1

    failures = 0
    ran = 0
    for b in blocks:
        if b.skipped:
            print(f"skip          {b.title}")
            continue
        ran += 1
        if args.list:
            print(f"run           {b.title}")
        elif not run_block(b, args.timeout):
            failures += 1
    if ran == 0:
        print("FAIL: every block is marked '# docs: skip' — nothing "
              "guards the quickstart")
        return 1
    print(f"{len(blocks)} block(s), {ran} run, {failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
