#!/usr/bin/env python
"""Serve-bench regression gate.

Compares a candidate benchmarks/serve_bench.py result against the committed
baseline in results/serve_bench.json and exits non-zero when throughput or
tail latency regressed beyond tolerance.  Rows are matched on
(scenario, engine, mode); a baseline row missing from the candidate is a
failure (a silently-dropped mode is a regression too).

Checks per row:
  * tokens_per_s      >= baseline * (1 - --tps-tol)
  * per-token p99 ms  <= baseline * (1 + --p99-tol)

Additionally gates the paged-attention kernel's bytes-read model
(results/kernel_bench.json, regenerated with --run): the kernel's KV
traffic must stay below the full-table gather path's at every uniform
occupancy >= 50%, must show at least a 4x reduction at 25% occupancy
(traffic scaling with actual kv length is the kernel's whole point —
DESIGN.md §Paged-attention kernel), and the int8-pool variant must cut
the kernel's own traffic by a further >= 1.8x (dequant-in-VMEM).

The kernel-tuning table (results/kernel_tuning.json) is gated too
(``check_kernel_tuning``): its schema must validate with tuned <= default
on every entry, one swept arch must cover the full phase x occupancy
grid, and kernel_bench's re-measured tuned timings must stay within
--tuned-tol of the default config's (nightly the table itself is
re-swept by ``python -m repro.kernels.autotune --check``).

Also gates the exposed-comm-time model (results/comm_bench.json,
regenerated with --run): on the gated NVLink rows the ladder schedule
must hide >= 30% of the exposed comm time standard mode pays at TP >= 2,
and the int8-compressed wire must carry >= 1.9x fewer bytes than bf16
(DESIGN.md §Communication overlap).

KV memory-tier gates (``check_serve_memory``, hard invariants on the
candidate serve rows — DESIGN.md §KV memory tiers): every paged-int8 row
must admit >= 1.8x the fp row's worst-case concurrent rows at equal pool
bytes, and the ``overload`` scenario must engage preemption while
completing every request.

Default tolerances are deliberately loose (CI machines are noisy and the
reduced-config bench runs on one CPU): the gate exists to catch the
engine accidentally serializing, not 5% jitter.

Usage:
    # compare two files
    python scripts/check_bench.py --candidate results/serve_bench.tmp.json

    # run a fresh reduced-config bench (same config as the baseline) and
    # compare it — what the nightly CI job does
    python scripts/check_bench.py --run
"""

import argparse
import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# baseline config keys replayed to serve_bench.py on --run (apples-to-apples)
_REPLAY = [
    "arch", "engine", "requests", "rate", "slots", "max_prompt", "max_new",
    "shared_len", "vocab", "block_size", "prefill_budget", "layers",
    "d_model", "temperature", "seed", "modes", "scenarios",
    "spec", "spec_k", "spec_temperature", "pallas", "int8", "comm",
]


def _key(row):
    return (row.get("scenario", "poisson"), row.get("engine", "ragged"),
            row["mode"])


def run_bench(baseline: dict, out_path: Path) -> None:
    cmd = [sys.executable, str(ROOT / "benchmarks" / "serve_bench.py"),
           "--out", str(out_path)]
    cfg = baseline.get("config", {})
    for k in _REPLAY:
        if k in cfg:
            cmd += [f"--{k.replace('_', '-')}", str(cfg[k])]
    print("+", " ".join(cmd))
    subprocess.run(cmd, check=True, stdout=subprocess.DEVNULL)


def compare(baseline: dict, candidate: dict, tps_tol: float,
            p99_tol: float) -> int:
    base_rows = {_key(r): r for r in baseline["rows"]}
    cand_rows = {_key(r): r for r in candidate["rows"]}
    failures = 0
    for key, base in sorted(base_rows.items()):
        cand = cand_rows.get(key)
        name = "/".join(key)
        if cand is None:
            print(f"FAIL {name}: row missing from candidate")
            failures += 1
            continue
        tps_floor = base["tokens_per_s"] * (1.0 - tps_tol)
        ok_tps = cand["tokens_per_s"] >= tps_floor
        base_p99 = base["per_token_latency_ms"]["p99"]
        cand_p99 = cand["per_token_latency_ms"]["p99"]
        ok_p99 = (base_p99 is None or cand_p99 is None or
                  cand_p99 <= base_p99 * (1.0 + p99_tol))
        status = "ok  " if ok_tps and ok_p99 else "FAIL"
        print(f"{status} {name}: tok/s {cand['tokens_per_s']:.1f} "
              f"(floor {tps_floor:.1f}), p99 "
              f"{'-' if cand_p99 is None else f'{cand_p99:.2f}ms'} "
              f"(ceil {'-' if base_p99 is None else f'{base_p99 * (1 + p99_tol):.2f}ms'})")
        failures += 0 if ok_tps and ok_p99 else 1
    return failures


def check_serve_memory(candidate: dict) -> int:
    """KV memory-tier gates on the candidate rows (hard invariants, not
    baseline-relative — DESIGN.md §KV memory tiers):

      * every (scenario, mode) with a fp ``paged`` row must carry a
        ``paged-int8`` row whose ``effective_slots`` (worst-case rows
        admitted at EQUAL pool bytes) is >= 1.8x the fp row's;
      * the ``overload`` scenario must be present, actually engage
        preemption, and complete every request — oversubscription must
        never drop or deadlock a request.
    """
    rows = candidate["rows"]
    by = {(r.get("scenario"), r["mode"], r.get("engine")): r for r in rows}
    failures = 0
    pairs = 0
    for (sc, m, e), r in sorted(by.items(), key=lambda kv: str(kv[0])):
        if e != "paged-int8":
            continue
        base = by.get((sc, m, "paged"))
        if base is None:
            continue
        pairs += 1
        ratio = r["effective_slots"] / max(base["effective_slots"], 1)
        ok = ratio >= 1.8
        print(f"{'ok  ' if ok else 'FAIL'} kv_int8/{sc}/{m}: "
              f"effective_slots {r['effective_slots']} vs fp "
              f"{base['effective_slots']} (x{ratio:.2f}, need >= 1.8)")
        failures += 0 if ok else 1
    if pairs == 0:
        print("FAIL kv_int8: no paged-int8 rows paired with fp paged rows")
        failures += 1

    saw_overload = preempted = False
    for r in rows:
        if r.get("scenario") != "overload":
            continue
        saw_overload = True
        ok = r["completed"] == r["requests"]
        preempted |= r.get("preemptions", 0) > 0
        print(f"{'ok  ' if ok else 'FAIL'} overload/{r['engine']}/"
              f"{r['mode']}: {r['completed']}/{r['requests']} completed, "
              f"{r.get('preemptions', 0)} preemptions "
              f"{r.get('swapped_out_blocks', 0)} blocks swapped")
        failures += 0 if ok else 1
    if not saw_overload:
        print("FAIL overload: scenario rows missing")
        failures += 1
    elif not preempted:
        print("FAIL overload: preemption never engaged (pool not "
              "oversubscribed enough to test the memory tier)")
        failures += 1
    return failures


def check_kernel_bench(path: Path) -> int:
    """Gate the paged-attention kernel's bytes-read model: traffic must
    track actual kv length, not table width.  Rows come from
    benchmarks/kernel_bench.py; the model is analytical (deterministic),
    so this is a hard invariant, not a tolerance check.

    Prefill rows gate the ragged q-tiled mode the same way: the kernel's
    chunk-append traffic is O(sum_b tiles * ceil(tile_hi / bs)) — each
    row's own causal extent — while the gather path materialises the
    O(table width) view for every row, so at the bench's mixed-history
    shape the kernel must read >= 2x fewer KV bytes than the full-width
    gather and never more than the live-sliced gather."""
    if not path.exists():
        print(f"FAIL kernel_bench: {path} missing "
              "(run benchmarks/kernel_bench.py)")
        return 1
    rows = json.loads(path.read_text())["rows"]
    failures = 0
    saw_25 = saw_50 = saw_prefill = False
    for r in rows:
        if r.get("scenario") == "prefill":
            saw_prefill = True
            ok = (r["bytes_kernel"] <= r["bytes_gather_sliced"]
                  and r["reduction_vs_full"] >= 2.0
                  and r.get("bytes_kernel_tuned", 1 << 62)
                  <= r["bytes_gather_sliced"])
            print(f"{'ok  ' if ok else 'FAIL'} kernel_bench/prefill: "
                  f"kernel {r['bytes_kernel']} B "
                  f"(tuned {r.get('bytes_kernel_tuned')} B) vs gather "
                  f"{r['bytes_gather_full']} B "
                  f"(x{r['reduction_vs_full']} reduction, need >= 2.0)")
            failures += 0 if ok else 1
            continue
        if r.get("scenario") != "uniform":
            continue
        occ = r["occupancy"]
        ok = True
        if occ >= 0.5:
            saw_50 = True
            ok &= r["bytes_kernel"] <= r["bytes_gather_full"]
        if abs(occ - 0.25) < 1e-6:
            saw_25 = True
            ok &= r["reduction_vs_full"] >= 4.0
        # int8 pools must cut the kernel's own traffic by >= 1.8x more —
        # the dequant-in-VMEM win stacks on the occupancy win (a missing
        # field is a failure: the int8 model must not silently vanish)
        ok &= r.get("reduction_int8_vs_fp", 0.0) >= 1.8
        print(f"{'ok  ' if ok else 'FAIL'} kernel_bench/occ{occ}: "
              f"kernel {r['bytes_kernel']} B vs gather "
              f"{r['bytes_gather_full']} B "
              f"(x{r['reduction_vs_full']} reduction, "
              f"int8 x{r.get('reduction_int8_vs_fp', 0.0)} further)")
        failures += 0 if ok else 1
    # an artifact without the gated rows must fail, not pass vacuously —
    # the same rule compare() applies to dropped serve rows
    if not (saw_25 and saw_50):
        print("FAIL kernel_bench: gated occupancy rows missing "
              "(need uniform rows at 0.25 and >= 0.5)")
        failures += 1
    if not saw_prefill:
        print("FAIL kernel_bench: prefill row missing (the ragged "
              "q-tiled append mode must stay in the gated artifact)")
        failures += 1
    return failures


def check_kernel_tuning(table_path: Path, bench_path: Path,
                        tuned_tol: float) -> int:
    """Gate the committed kernel-tuning table (results/kernel_tuning.json)
    and the tuned timing columns kernel_bench carries.

    Table checks are hard invariants: the schema must validate
    (kernels/autotune.validate_table — includes tuned_us <= default_us on
    every entry, i.e. the sweep may never persist a config slower than
    the deterministic fallback, and tuned_us >= the roofline bound), and
    at least one swept arch must cover the full phase x occupancy-bucket
    grid so a partial sweep cannot silently pass.

    The kernel_bench timing check is tolerance-based: re-measured
    ``t_kernel_tuned_us`` may not exceed ``t_kernel_us`` by more than
    ``tuned_tol`` (interpret-mode timings on shared CI hardware are
    noisy, and the tuned config legitimately equals the default at some
    occupancies); every row must CARRY the tuned columns — a row that
    drops them would pass vacuously otherwise."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.kernels import autotune

    if not table_path.exists():
        print(f"FAIL kernel_tuning: {table_path} missing "
              "(run python -m repro.kernels.autotune --sweep)")
        return 1
    failures = 0
    try:
        table = autotune.load_table(table_path, strict=True)
    except ValueError as e:
        print(f"FAIL kernel_tuning: {e}")
        return 1
    entries = table["entries"]
    by_arch = {}
    for key in entries:
        arch, phase, occ = key.rsplit("/", 2)
        by_arch.setdefault(arch, set()).add((phase, occ))
    full_grid = {(p, f"occ{b}") for p in autotune.PHASES
                 for b in autotune.OCC_BUCKETS}
    complete = [a for a, got in by_arch.items() if got >= full_grid]
    if not complete:
        print("FAIL kernel_tuning: no arch covers the full "
              f"phase x occupancy grid ({sorted(by_arch)})")
        failures += 1
    else:
        n = len(entries)
        print(f"ok   kernel_tuning: {n} entries, full grid for "
              f"{', '.join(sorted(complete))} (tuned <= default on all)")

    if not bench_path.exists():
        return failures  # check_kernel_bench already failed the artifact
    rows = json.loads(bench_path.read_text())["rows"]
    for r in rows:
        tag = (f"occ{r['occupancy']}" if r["scenario"] == "uniform"
               else r["scenario"])
        if "t_kernel_tuned_us" not in r:
            print(f"FAIL kernel_tuning/{tag}: tuned timing column missing")
            failures += 1
            continue
        ceil_us = r["t_kernel_us"] * (1.0 + tuned_tol)
        ok = r["t_kernel_tuned_us"] <= ceil_us
        print(f"{'ok  ' if ok else 'FAIL'} kernel_tuning/{tag}: tuned "
              f"{r['t_kernel_tuned_us']:.1f}us "
              f"[splits={r['tuned_num_splits']} q_tile={r['tuned_q_tile']}]"
              f" vs default {r['t_kernel_us']:.1f}us "
              f"(ceil {ceil_us:.1f}us)")
        failures += 0 if ok else 1
    return failures


def check_comm_bench(path: Path) -> int:
    """Gate the exposed-comm-time model (benchmarks/comm_bench.py): on
    every gated ladder row, ladder must hide >= 30% of the exposed comm
    time STANDARD pays at the same (hw, tp, phase, wire format), and the
    compressed wire must carry >= 1.9x fewer bytes than bf16.  The model
    is analytical (deterministic), so like check_kernel_bench these are
    hard invariants — the 0.30 floor is loose on purpose: it catches the
    ladder schedule accidentally serializing, not model drift."""
    if not path.exists():
        print(f"FAIL comm_bench: {path} missing "
              "(run benchmarks/comm_bench.py)")
        return 1
    rows = json.loads(path.read_text())["rows"]
    failures = 0
    gated_pairs = 0
    for r in rows:
        if r.get("scenario") != "model" or not r.get("gated"):
            continue
        if r["mode"] == "ladder":
            if r["tp"] < 2:
                continue
            gated_pairs += 1
            ok = r["hidden_vs_standard"] >= 0.30
            print(f"{'ok  ' if ok else 'FAIL'} comm_bench/{r['hw']}/"
                  f"tp{r['tp']}/{r['phase']}/{r['comm']}: ladder hides "
                  f"{100 * r['hidden_vs_standard']:.0f}% of standard's "
                  f"exposed comm (need >= 30%)")
            failures += 0 if ok else 1
        if r["comm"] == "compressed":
            ok = r.get("wire_reduction", 0.0) >= 1.9
            if not ok:
                print(f"FAIL comm_bench/{r['hw']}/tp{r['tp']}/{r['phase']}: "
                      f"int8 wire reduction x{r.get('wire_reduction', 0.0)} "
                      "< 1.9")
                failures += 1
    # vacuous-pass protection: the gated rows must exist at TP >= 2
    if gated_pairs == 0:
        print("FAIL comm_bench: no gated ladder rows at tp >= 2 "
              "(gate would pass vacuously)")
        failures += 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=str(ROOT / "results" / "serve_bench.json"))
    ap.add_argument("--candidate", default=None,
                    help="candidate result JSON (omit with --run)")
    ap.add_argument("--run", action="store_true",
                    help="run a fresh bench with the baseline's config "
                         "into results/serve_bench.tmp.json and compare it "
                         "(also regenerates the kernel_bench bytes model)")
    ap.add_argument("--tps-tol", type=float, default=0.5,
                    help="max fractional tokens/sec drop (default 0.5)")
    ap.add_argument("--p99-tol", type=float, default=1.0,
                    help="max fractional p99 increase (default 1.0 = 2x)")
    ap.add_argument("--kernel-bench",
                    default=str(ROOT / "results" / "kernel_bench.json"),
                    help="kernel_bench artifact to gate (bytes-read model)")
    ap.add_argument("--kernel-tuning",
                    default=str(ROOT / "results" / "kernel_tuning.json"),
                    help="committed kernel tuning table to gate")
    ap.add_argument("--tuned-tol", type=float, default=0.5,
                    help="max fractional excess of the re-measured tuned "
                         "kernel time over the default config's (noise "
                         "headroom; the table itself is gated hard)")
    ap.add_argument("--comm-bench",
                    default=str(ROOT / "results" / "comm_bench.json"),
                    help="comm_bench artifact to gate (exposed-comm model)")
    args = ap.parse_args(argv)

    baseline = json.loads(Path(args.baseline).read_text())
    kernel_path = Path(args.kernel_bench)
    comm_path = Path(args.comm_bench)
    if args.run:
        cand_path = ROOT / "results" / "serve_bench.tmp.json"
        run_bench(baseline, cand_path)
        kernel_path = ROOT / "results" / "kernel_bench.tmp.json"
        cmd = [sys.executable, str(ROOT / "benchmarks" / "kernel_bench.py"),
               "--out", str(kernel_path)]
        print("+", " ".join(cmd))
        subprocess.run(cmd, check=True, stdout=subprocess.DEVNULL)
        comm_path = ROOT / "results" / "comm_bench.tmp.json"
        cmd = [sys.executable, str(ROOT / "benchmarks" / "comm_bench.py"),
               "--out", str(comm_path)]
        print("+", " ".join(cmd))
        subprocess.run(cmd, check=True, stdout=subprocess.DEVNULL)
    elif args.candidate:
        cand_path = Path(args.candidate)
    else:
        ap.error("need --candidate FILE or --run")
    candidate = json.loads(Path(cand_path).read_text())

    failures = compare(baseline, candidate, args.tps_tol, args.p99_tol)
    failures += check_serve_memory(candidate)
    failures += check_kernel_bench(kernel_path)
    failures += check_kernel_tuning(Path(args.kernel_tuning), kernel_path,
                                    args.tuned_tol)
    failures += check_comm_bench(comm_path)
    if failures:
        print(f"{failures} bench regression(s) vs {args.baseline}")
    else:
        print("bench within tolerance of baseline")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
