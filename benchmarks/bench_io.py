"""Shared result-writing policy for the benches.

A bench run always lands its JSON in an UNTRACKED ``<stem>.tmp.json``
scratch file next to the requested ``--out`` path (``results/*.tmp.json``
is gitignored); only under ``--update-baseline`` is the scratch then
atomically renamed (``os.replace``) onto the committed baseline.  This
keeps ``git status`` clean after exploratory runs, makes refreshing a
committed artifact an explicit act, and guarantees a crashed or
interrupted bench can never leave a half-written baseline behind —
readers see either the old complete file or the new complete file.

``scripts/check_bench.py --run`` passes explicit ``results/*.tmp.json``
candidate paths; those are already scratch, so they are written in place
and ``--update-baseline`` has nothing further to do.
"""

import json
import os
from pathlib import Path

_TMP_SUFFIX = ".tmp.json"


def scratch_path(out) -> Path:
    """The untracked scratch twin of ``out`` (identity if already one)."""
    out = Path(out)
    if out.name.endswith(_TMP_SUFFIX):
        return out
    return out.with_name(out.name[: -len(".json")] + _TMP_SUFFIX)


def write_record(record: dict, out, update_baseline: bool) -> Path:
    """Write ``record`` under the scratch-then-promote policy.

    Returns the path the result actually lives at, and prints it — a run
    without ``--update-baseline`` must say loudly that the committed
    baseline was NOT touched.
    """
    out = Path(out)
    out.parent.mkdir(parents=True, exist_ok=True)
    scratch = scratch_path(out)
    scratch.write_text(json.dumps(record, indent=1))
    if update_baseline and scratch != out:
        os.replace(scratch, out)
        print(f"[bench_io] baseline updated: {out}")
        return out
    if scratch != out:
        print(f"[bench_io] wrote scratch {scratch} "
              f"(baseline {out.name} untouched; pass --update-baseline "
              "to promote)")
    return scratch


def add_update_baseline_arg(ap) -> None:
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="atomically promote the scratch result onto the committed "
             "--out baseline (default: write only the untracked "
             "*.tmp.json scratch)")
