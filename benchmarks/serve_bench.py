"""Engine-level serving benchmark: Ladder vs Standard residual under
synthetic traffic through the continuous-batching engines.

Unlike benchmarks/run.py (per-step analytical timeline), this measures the
SERVING system end-to-end on real executed steps: request admission, paged
(or ragged) prefill/decode interleaving, block reuse — and reports
tokens/sec plus p50/p99 per-token latency (time between consecutive tokens
of a request, first token measured from arrival, i.e. TTFT).  Two traffic
scenarios per residual mode:

* ``poisson``        — independent prompts, Poisson arrivals (PR-1 shape).
* ``shared_prefix``  — the same Poisson arrivals behind one shared system
  prompt: the regime the paged KV cache targets.  Rows add the paged
  engine's prefix-hit rate and block utilization so regressions in block
  economy are as visible as throughput regressions.
* ``overload``       — the same arrivals against a pool deliberately too
  small for the offered load, served by the oversubscribing preemptive
  scheduler (serving/memory.py): rows report preemption/resume/swap
  counts, and check_bench.py gates that every request still completes.

Every paged row also reports the pool economics (DESIGN.md §KV memory
tiers): per-layer pool bytes, bytes per slot, and ``effective_slots`` —
how many worst-case rows fit the FP pool's byte budget under that row's
KV storage mode.  The ``paged-int8`` variant rows store the pool int8
with per-(token, head) scales; check_bench.py gates that their
effective_slots is >= 1.8x the fp rows' at equal pool bytes.

With ``--pallas on`` (the default), each scenario x residual mode adds a
``paged+pallas`` row serving the SAME trace through the block-table-native
paged-attention kernel (kernels/paged_attention.py) — bit-identical
tokens, so its throughput column isolates the read-path implementation;
off-TPU the kernel runs in interpret mode and the row only guards against
pathological regressions (the bytes-read win is benchmarks/kernel_bench.py).

With ``--spec`` (default: ngram), each scenario x residual mode also runs
a speculative-decoding row (engine ``paged+spec-<mode>``) reporting
accept-rate and tokens-per-forward alongside throughput.  Spec rows decode
greedily by default (``--spec-temperature``) — the common deployment for
speculation, and the regime where a random-init reduced model loops enough
for prompt-lookup drafting to engage; outputs stay bit-identical to plain
decode either way (DESIGN.md §Speculative decoding).  A ``paged-greedy``
plain row runs at the SAME temperature as the spec rows so the speculation
win reads apples-to-apples (the sampled ``paged`` row pays the full-vocab
sort/gumbel path the greedy dispatch skips — comparing spec against it
would conflate the two effects).

On CPU at TP=1 the residual modes execute the same collectives (none), so
the comparison is an engine-overhead / correctness harness here and becomes
a communication-overlap measurement on a real TP mesh.
``scripts/check_bench.py`` gates CI on the JSON this writes.

    PYTHONPATH=src python benchmarks/serve_bench.py \
        --requests 12 --rate 50 --out results/serve_bench.json
"""

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from bench_io import add_update_baseline_arg, write_record     # noqa: E402

from repro.configs import REGISTRY, ResidualMode               # noqa: E402
from repro.models import transformer as tfm                    # noqa: E402
from repro.serving import scheduler as sched                   # noqa: E402


def _percentiles(xs, ps=(50, 99)):
    if not xs:
        return {f"p{p}": None for p in ps}
    arr = np.asarray(xs, np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in ps}


def _overload_pool(args, s_max):
    """(num_blocks, oversubscribe) for the overload scenario: room for
    ~1.5 worst-case rows while `slots` stay admitted via oversubscription,
    so the scheduler MUST preempt to keep everyone moving."""
    worst = -(-(s_max - 1) // args.block_size)
    return worst + max(1, worst // 2), 4.0


def _make_engine(cfg, params, args, s_max, spec: str, use_pallas: bool,
                 kv_quant: str = "fp", overload: bool = False,
                 comm: str = "sync"):
    """Engine for one bench row: ragged oracle, plain paged, or paged with
    the requested speculative drafter; `use_pallas` routes the paged
    attention read through the block-table-native kernel, `kv_quant`
    selects fp or int8 pool storage, `overload` swaps in the tiny
    oversubscribed pool driven by the preemptive scheduler, and `comm`
    selects the TP AllReduce mode (parallel/overlap.py)."""
    if args.engine == "ragged":
        return sched.ContinuousServingEngine(
            cfg, params, batch_slots=args.slots, s_max=s_max,
            max_prefills_per_step=1)
    pal = dict(use_pallas=True) if use_pallas else {}
    mem = dict(kv_quant=kv_quant)
    if comm != "sync":
        mem.update(comm_overlap=True)
    if overload:
        num_blocks, over = _overload_pool(args, s_max)
        mem.update(num_blocks=num_blocks, oversubscribe=over)
    if spec != "off":
        from repro.serving.speculative import (SpeculativePagedEngine,
                                               derive_draft_cfg)
        kw = {}
        if spec == "draft":
            dcfg = derive_draft_cfg(cfg, max(1, args.layers // 2))
            kw = dict(draft_cfg=dcfg,
                      draft_params=tfm.init_params(dcfg, jax.random.key(1)))
        return SpeculativePagedEngine(
            cfg, params, batch_slots=args.slots, s_max=s_max,
            block_size=args.block_size,
            max_prefill_tokens=args.prefill_budget,
            spec_mode=spec, spec_k=args.spec_k, **kw, **pal, **mem)
    return sched.PagedServingEngine(
        cfg, params, batch_slots=args.slots, s_max=s_max,
        block_size=args.block_size,
        max_prefill_tokens=args.prefill_budget, **pal, **mem)


def _warm_paged_variants(engine, longest: int, temperature: float):
    """Compile every reachable (prefill-bucket x block-table-width) and
    (decode-or-verify x width) jit variant outside the clock.

    Prefix-cache hits and chunking make chunk length and table width
    independent — a 5-token tail chunk can attend through a 4-block-wide
    table — and decode/verify widths depend on the live rows' kv lengths,
    so traffic-shaped warmup cannot cover the grid reliably; each variant
    instead runs one MASKED step (length 0 / active all-False: every
    position is -1, K/V writes drop, sampled tokens discarded — engine
    state is untouched).

    The kernel-tuning dispatch (engine.build_paged_steps's ``_tune``)
    adds NO extra variants to this grid: its (phase, occupancy-bucket)
    key is a pure function of the table width already swept here, so
    warming every width also warms every tuned launch geometry — each
    row's ``n_jit_variants`` pins the compiled-variant count so a
    tuning-key change that silently explodes retraces fails review."""
    import jax.numpy as jnp
    from repro.serving.sampler import GREEDY_EPS

    bs = engine.block_size
    budget = engine.scheduler.max_prefill_tokens
    greedy = temperature <= GREEDY_EPS
    lbs, b = [], 16
    while b < min(longest, budget):
        lbs.append(b)
        b *= 2
    lbs.append(b)
    widths = []
    w = 1
    while w < engine.max_blocks:
        widths.append(w)
        w *= 2
    widths.append(engine.max_blocks)
    nb = engine.batch_slots
    zf = lambda n: jnp.zeros((n,), jnp.float32)
    zi = lambda n: jnp.zeros((n,), jnp.int32)
    for lb in lbs:
        # smallest real chunk of bucket lb (the lowest bucket rounds every
        # chunk of 1..lb tokens up, so its smallest chunk is 1 token)
        min_chunk = 1 if lb == lbs[0] else lb // 2 + 1
        min_blocks = -(-min_chunk // bs)
        for w in widths:
            if w < min_blocks:
                continue  # unreachable: table can't hold the chunk
            engine.caches, _ = engine._prefill_chunk(
                engine.params, engine.caches,
                jnp.zeros((1, lb), jnp.int32),
                jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
                jnp.zeros((1, w), jnp.int32),
                jnp.asarray([temperature], jnp.float32), zi(1),
                jnp.asarray([1.0], jnp.float32), zi(1))
    spec_k = getattr(engine, "spec_k", None)
    for w in widths:
        bt = jnp.zeros((nb, w), jnp.int32)
        inactive = jnp.zeros((nb,), bool)
        if spec_k is not None:
            # speculative engines decode through verify, never plain decode
            base = (engine.params, engine.caches,
                    jnp.zeros((nb, spec_k + 1), jnp.int32), zi(nb),
                    inactive, jnp.ones((nb,), jnp.int32), bt)
            if greedy:
                engine.caches, _ = engine._verify_greedy(*base)
            else:
                engine.caches, _ = engine._verify(
                    *base, zf(nb) + temperature, zi(nb), zf(nb) + 1.0,
                    zi(nb))
        else:
            base = (engine.params, engine.caches, zi(nb), zi(nb), inactive,
                    bt)
            if greedy:
                engine.caches, _ = engine._decode_greedy(*base)
            else:
                engine.caches, _ = engine._decode(
                    *base, zf(nb) + temperature, zi(nb), zf(nb) + 1.0,
                    zi(nb))


def _n_jit_variants(engine) -> int:
    """Compiled-variant count across the engine's jitted step functions —
    the (bucket x width x phase) grid _warm_paged_variants covers, plus
    anything the traffic forced.  Reported per row so retrace explosions
    (e.g. a tuning key that varies per step) show up in the artifact."""
    fns = ("_prefill_chunk", "_decode", "_decode_greedy", "_verify",
           "_verify_greedy", "_prefill")
    total = 0
    for name in fns:
        fn = getattr(engine, name, None)
        size = getattr(fn, "_cache_size", None)
        if size is not None:
            total += size()
    return total


def _pool_economics(cfg, args, s_max, engine) -> dict:
    """Per-layer KV pool economics for a paged row: pool bytes under this
    row's storage mode, and how many WORST-CASE rows the fp pool's byte
    budget would admit under it (the equal-pool-bytes concurrency gate)."""
    import jax.numpy as jnp

    from repro.serving.kv_cache import kv_block_bytes
    bs = args.block_size
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    esize = jnp.dtype(cfg.dtype).itemsize
    fp_block = kv_block_bytes(bs, hkv, hd, esize)
    block_bytes = kv_block_bytes(bs, hkv, hd, esize, engine.kv_quant)
    budget = engine.num_blocks * fp_block    # equal-bytes yardstick
    worst = -(-(s_max - 1) // bs)
    return dict(
        kv_quant=engine.kv_quant,
        pool_blocks=engine.num_blocks,
        pool_bytes_per_layer=engine.num_blocks * block_bytes,
        pool_bytes_per_row=round(engine.num_blocks * block_bytes
                                 / args.slots),
        effective_slots=(budget // block_bytes) // worst,
    )


def bench_mode(mode: str, scenario: str, args, variant=None) -> dict:
    """One bench row.  `variant` is (engine_label, spec_mode, temperature,
    use_pallas, kv_quant, overload, comm); None means the plain engine at
    the sampled default."""
    (label, spec, temperature, use_pallas, kv_quant, overload,
     comm) = variant or (args.engine, "off", args.temperature, False, "fp",
                         False, "sync")
    cfg = REGISTRY[args.arch].reduced(
        n_layers=args.layers, d_model=args.d_model, n_heads=4,
        d_ff=2 * args.d_model, vocab_size=args.vocab,
    ).replace(residual_mode=ResidualMode(mode))
    params = tfm.init_params(cfg, jax.random.key(0))
    shared = []
    if scenario == "shared_prefix":
        rng = np.random.default_rng(args.seed + 1)
        shared = rng.integers(0, cfg.vocab_size, args.shared_len).tolist()
    s_max = len(shared) + args.max_prompt + args.max_new + 1
    trace = sched.poisson_trace(
        args.requests, args.rate, seed=args.seed,
        prompt_lens=(4, args.max_prompt),
        max_new=(max(2, args.max_new // 2), args.max_new),
        vocab=cfg.vocab_size,
        sampling=lambda rid: sched.SamplingParams(
            temperature=temperature, top_k=40, top_p=0.95, seed=rid))
    for r in trace:
        r.prompt = shared + r.prompt

    engine = _make_engine(cfg, params, args, s_max, spec, use_pallas,
                          kv_quant=kv_quant, overload=overload, comm=comm)

    # warmup: compile EVERY prefill bucket + the decode graph outside the
    # timed run (jit caches are shared through the process-wide tracing cache
    # only per-callable, so warm the engine's own jitted fns).  The paged
    # engines additionally retrace per block-table width bucket
    # (scheduler._bt_width), so the warmup spans short AND long prompts AND
    # runs each request to completion ALONE — a concurrent warmup batch
    # would decode every row at the batch-max width and leave the small
    # width buckets to compile inside the timed run.
    longest = max(len(r.prompt) for r in trace)
    lengths, b = [2], 16
    while b < longest:
        lengths.append(b)
        b *= 2
    lengths.append(b)
    for i, lp in enumerate(lengths):
        engine.submit(sched.Request(
            rid=-1 - i, prompt=[1] * min(lp, s_max - 2), max_new_tokens=2,
            sampling=sched.SamplingParams(temperature=temperature)))
        engine.run()
    if hasattr(engine, "_prefill_chunk"):  # paged engines only
        _warm_paged_variants(engine, longest, temperature)
    engine.scheduler.finished.clear()
    if hasattr(engine, "reset_stats"):
        engine.reset_stats()

    t0 = time.monotonic()
    finished, tok_times = sched.serve_trace(engine, trace)
    wall = time.monotonic() - t0

    arrivals = {r.rid: r.arrival for r in trace}
    ttft, itl = [], []
    for rid, times in tok_times.items():
        if not times:
            continue
        ttft.append(times[0] - arrivals[rid])
        itl.extend(b - a for a, b in zip(times, times[1:]))
    n_tok = sum(len(f.tokens) for f in finished.values())

    row = dict(
        mode=mode, scenario=scenario, engine=label, arch=args.arch,
        requests=len(trace), completed=len(finished), slots=args.slots,
        tokens=n_tok,
        wall_s=round(wall, 4),
        tokens_per_s=round(n_tok / max(wall, 1e-9), 2),
        per_token_latency_ms=_percentiles([x * 1e3 for x in itl]),
        ttft_ms=_percentiles([x * 1e3 for x in ttft]),
        n_jit_variants=_n_jit_variants(engine),
    )
    if args.engine == "paged":
        st = engine.stats()
        row.update(
            prefix_hit_rate=round(st["prefix_hit_rate"], 4),
            block_util_mean=round(st["block_util_mean"], 4),
            block_util_peak=round(st["block_util_peak"], 4),
            block_allocs=st["total_block_allocs"],
            deferred_admissions=st["deferred_admissions"],
            **_pool_economics(cfg, args, s_max, engine),
        )
        if "preemptions" in st:
            row.update(
                preemptions=st["preemptions"],
                resumes=st["resumes"],
                swapped_out_blocks=st["swapped_out_blocks"],
            )
    if spec != "off":
        row.update(
            accept_rate=round(st["accept_rate"], 4),
            tokens_per_forward=round(st["tokens_per_forward"], 4),
            verify_forwards=st["verify_forwards"],
            rolled_back_blocks=st["rolled_back_blocks"],
        )
    assert len(finished) == len(trace), "requests dropped"
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--engine", default="paged", choices=["paged", "ragged"])
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--shared-len", type=int, default=32,
                    help="system-prompt length for the shared_prefix "
                         "scenario")
    ap.add_argument("--vocab", type=int, default=256,
                    help="reduced vocab size (small enough that greedy "
                         "decode of a random-init model develops the loops "
                         "prompt-lookup drafting feeds on)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=128)
    ap.add_argument("--spec", default="ngram",
                    help="comma list of speculative rows to add per "
                         "scenario/mode (ngram, draft); 'off' disables")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--spec-temperature", type=float, default=0.0,
                    help="sampling temperature for the speculative rows "
                         "(greedy by default)")
    ap.add_argument("--int8", default="on", choices=["on", "off"],
                    help="add a paged-int8 row per scenario/mode (int8 KV "
                         "pool with per-token scales; reports pool "
                         "economics — check_bench gates >= 1.8x "
                         "effective_slots vs fp at equal pool bytes)")
    ap.add_argument("--pallas", default="on", choices=["on", "off"],
                    help="add a paged+pallas row per scenario/mode (paged "
                         "attention through the block-table-native kernel; "
                         "interpret mode off-TPU, so wall clock here only "
                         "guards against pathological regressions — the "
                         "bytes-read win lives in kernel_bench.py)")
    ap.add_argument("--comm", default="on", choices=["on", "off"],
                    help="add a paged-overlap row per scenario/mode (TP "
                         "AllReduce as the chunked overlapped ring; at the "
                         "bench's TP=1 the ring is the identity, so the "
                         "row guards engine overhead/correctness — the "
                         "exposed-comm win lives in comm_bench.py)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--modes", default="ladder,standard")
    ap.add_argument("--scenarios",
                    default="poisson,shared_prefix,overload")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "results" / "serve_bench.json"))
    add_update_baseline_arg(ap)
    args = ap.parse_args()

    variants = [(args.engine, "off", args.temperature, False, "fp", False,
                 "sync")]
    if args.engine == "paged" and args.pallas == "on":
        # same traffic through the paged-attention kernel: tokens are
        # bit-identical, so any count difference is a bug, not jitter
        variants.append(("paged+pallas", "off", args.temperature, True,
                         "fp", False, "sync"))
    if args.engine == "paged" and args.int8 == "on":
        # same traffic on an int8 pool: tokens may differ within the
        # bounded logit error; the row's point is the pool economics
        # (2x+ rows per byte) and that throughput holds up
        variants.append(("paged-int8", "off", args.temperature, False,
                         "int8", False, "sync"))
    if args.engine == "paged" and args.comm == "on":
        # same traffic with the TP AllReduce in overlap (chunked ring)
        # mode: at the bench's TP=1 the ring degenerates to the identity,
        # so like the pallas row this is an overhead/correctness harness
        # here and becomes a comm-overlap measurement on a real TP mesh
        # (the modeled win is benchmarks/comm_bench.py)
        variants.append(("paged-overlap", "off", args.temperature, False,
                         "fp", False, "overlap"))
    if args.engine == "paged" and args.spec != "off":
        # a plain greedy row at the spec temperature (apples-to-apples
        # counterpart), then one row per requested drafter
        variants.append(("paged-greedy", "off", args.spec_temperature,
                         False, "fp", False, "sync"))
        variants += [(f"paged+spec-{sp}", sp, args.spec_temperature, False,
                      "fp", False, "sync")
                     for sp in (x.strip() for x in args.spec.split(","))
                     if sp]
    # the overload scenario exercises the preemptive memory tier only:
    # a fp and an int8 row on the deliberately-too-small pool
    overload_variants = [
        ("paged-preempt", "off", args.temperature, False, "fp", True,
         "sync"),
        ("paged-preempt-int8", "off", args.temperature, False, "int8",
         True, "sync"),
    ]
    scenarios = [sc.strip() for sc in args.scenarios.split(",")]
    if args.engine == "ragged" and "overload" in scenarios:
        # the memory tiers only exist on the paged path: a ragged run must
        # drop the scenario, not emit rows mislabeled paged-preempt*
        print("serve_bench: skipping overload scenario (--engine ragged)")
        scenarios = [sc for sc in scenarios if sc != "overload"]
    rows = [bench_mode(m.strip(), sc, args, variant=v)
            for sc in scenarios
            for m in args.modes.split(",")
            for v in (overload_variants if sc == "overload"
                      else variants)]
    cfg = {k: v for k, v in vars(args).items() if k != "update_baseline"}
    record = dict(bench="serve_bench", config=cfg, rows=rows)
    write_record(record, args.out, args.update_baseline)
    print(json.dumps(record, indent=1))
    for r in rows:
        extra = (f" hit={r['prefix_hit_rate']:.2f} "
                 f"util={r['block_util_mean']:.2f}"
                 if "prefix_hit_rate" in r else "")
        if "accept_rate" in r:
            extra += (f" accept={r['accept_rate']:.2f} "
                      f"tok/fwd={r['tokens_per_forward']:.2f}")
        if "effective_slots" in r:
            extra += (f" quant={r['kv_quant']} "
                      f"slots@budget={r['effective_slots']}")
        if "preemptions" in r:
            extra += (f" preempt={r['preemptions']} "
                      f"resume={r['resumes']}")
        extra += f" jits={r['n_jit_variants']}"
        print(f"serve_bench/{r['scenario']}/{r['engine']}/{r['mode']},"
              f"{1e6 / max(r['tokens_per_s'], 1e-9):.1f},"
              f"tok_per_s={r['tokens_per_s']} "
              f"p50={r['per_token_latency_ms']['p50']:.2f}ms "
              f"p99={r['per_token_latency_ms']['p99']:.2f}ms{extra}")


if __name__ == "__main__":
    main()
