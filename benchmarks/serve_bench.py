"""Engine-level serving benchmark: Ladder vs Standard residual under a
synthetic Poisson arrival trace through the continuous-batching engine.

Unlike benchmarks/run.py (per-step analytical timeline), this measures the
SERVING system end-to-end on real executed steps: request admission, ragged
prefill/decode interleaving, slot reuse — and reports tokens/sec plus
p50/p99 per-token latency (time between consecutive tokens of a request,
first token measured from arrival, i.e. TTFT).  On CPU at TP=1 the two
residual modes execute the same collectives (none), so the comparison is an
engine-overhead / correctness harness here and becomes a communication-
overlap measurement on a real TP mesh.

    PYTHONPATH=src python benchmarks/serve_bench.py \
        --requests 12 --rate 50 --out results/serve_bench.json
"""

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from repro.configs import REGISTRY, ResidualMode               # noqa: E402
from repro.models import transformer as tfm                    # noqa: E402
from repro.serving import scheduler as sched                   # noqa: E402


def _percentiles(xs, ps=(50, 99)):
    if not xs:
        return {f"p{p}": None for p in ps}
    arr = np.asarray(xs, np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in ps}


def bench_mode(mode: str, args) -> dict:
    cfg = REGISTRY[args.arch].reduced(
        n_layers=args.layers, d_model=args.d_model, n_heads=4,
        d_ff=2 * args.d_model, vocab_size=1024,
    ).replace(residual_mode=ResidualMode(mode))
    params = tfm.init_params(cfg, jax.random.key(0))

    s_max = args.max_prompt + args.max_new + 1
    trace = sched.poisson_trace(
        args.requests, args.rate, seed=args.seed,
        prompt_lens=(4, args.max_prompt), max_new=(2, args.max_new),
        vocab=cfg.vocab_size,
        sampling=lambda rid: sched.SamplingParams(
            temperature=args.temperature, top_k=40, top_p=0.95, seed=rid))

    engine = sched.ContinuousServingEngine(
        cfg, params, batch_slots=args.slots, s_max=s_max,
        max_prefills_per_step=1)

    # warmup: compile EVERY prefill bucket + the decode graph outside the
    # timed run (jit caches are shared through the process-wide tracing cache
    # only per-callable, so warm the engine's own jitted fns)
    lengths, b = [], 16
    while b < args.max_prompt:
        lengths.append(b)
        b *= 2
    lengths.append(b)
    for i, lp in enumerate(lengths):
        engine.submit(sched.Request(
            rid=-1 - i, prompt=[1] * min(lp, s_max - 2), max_new_tokens=2,
            sampling=sched.SamplingParams(temperature=args.temperature)))
    engine.run()
    engine.scheduler.finished.clear()

    t0 = time.monotonic()
    finished, tok_times = sched.serve_trace(engine, trace)
    wall = time.monotonic() - t0

    arrivals = {r.rid: r.arrival for r in trace}
    ttft, itl = [], []
    for rid, times in tok_times.items():
        if not times:
            continue
        ttft.append(times[0] - arrivals[rid])
        itl.extend(b - a for a, b in zip(times, times[1:]))
    n_tok = sum(len(f.tokens) for f in finished.values())

    row = dict(
        mode=mode, arch=args.arch, requests=len(trace),
        completed=len(finished), slots=args.slots, tokens=n_tok,
        wall_s=round(wall, 4),
        tokens_per_s=round(n_tok / max(wall, 1e-9), 2),
        per_token_latency_ms=_percentiles([x * 1e3 for x in itl]),
        ttft_ms=_percentiles([x * 1e3 for x in ttft]),
    )
    assert len(finished) == len(trace), "requests dropped"
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--modes", default="ladder,standard")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "results" / "serve_bench.json"))
    args = ap.parse_args()

    rows = [bench_mode(m.strip(), args) for m in args.modes.split(",")]
    record = dict(bench="serve_bench", config=vars(args), rows=rows)

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(record, indent=1))
    print(json.dumps(record, indent=1))
    for r in rows:
        print(f"serve_bench/{r['mode']},"
              f"{1e6 / max(r['tokens_per_s'], 1e-9):.1f},"
              f"tok_per_s={r['tokens_per_s']} "
              f"p50={r['per_token_latency_ms']['p50']:.2f}ms "
              f"p99={r['per_token_latency_ms']['p99']:.2f}ms")


if __name__ == "__main__":
    main()
