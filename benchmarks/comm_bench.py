"""TP AllReduce benchmark: bytes-on-wire + exposed-comm-time model.

The ladder residual's thesis is that the block-output AllReduce can hide
under the next sub-block's compute; parallel/overlap.py adds the explicit
chunked/compressed ring.  This bench pins both down the same two ways
kernel_bench does:

* **analytical model** (core/schedule.py) — per layer, per residual mode:

    wire bytes     2 (tp-1)/tp * t * d * 2       (bf16 ring; int8 wire pays
                                                  1 B/elem + 4 B per
                                                  256-element scale block)
    t_comm         chunks * latency + wire / link_bw
    exposed        STANDARD: 2 * t_comm          (consumed immediately)
                   LADDER:   max(0, t_comm - t_attn)
                             + max(0, t_comm - t_mlp)
                   DESYNC-n: 2 * t_comm / n      (survivors synchronous)

  ``hidden_vs_standard`` on ladder rows is the gated quantity: the
  fraction of STANDARD's exposed comm that LADDER hides at the same
  (hw, tp, phase, wire format).  scripts/check_bench.py requires
  >= 0.30 on the gated rows — loose on purpose; it catches accidental
  serialization of the schedule, not small model drift.  Gated rows are
  NVLink sync rows (the schedule itself) plus NVLink chunked *prefill*
  rows (bandwidth-dominated, where chunking pays off).  Ungated but
  reported: chunked decode (chunks multiply the 8us collective latency,
  so a decode sub-block genuinely cannot hide 4 chunks' worth — the
  model says use chunks=1 there) and all PCIe rows (25us latency
  swamps one sub-block of compute).  The compressed rows also gate the
  wire-byte reduction (>= 1.9x vs bf16).

* **measured step time** — wall time of jitted psum / ring / compressed
  ring at TP=2 on this host's (forced) 2 fake CPU devices.  Like
  kernel_bench's interpret-mode timings this column exists to catch
  pathological regressions and becomes meaningful on real links; the
  model rows are what check_bench gates.

    PYTHONPATH=src python benchmarks/comm_bench.py \
        --out results/comm_bench.json
"""

import argparse
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from bench_io import add_update_baseline_arg, write_record  # noqa: E402

# the measured half wants 2 devices; force them BEFORE jax initialises
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        " --xla_force_host_platform_device_count=2"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import ResidualMode  # noqa: E402
from repro.core.schedule import (  # noqa: E402
    HWS,
    ar_wire_bytes,
    comm_time,
    exposed_comm,
    layer_cost,
)
from repro.parallel import compat, overlap  # noqa: E402

MODES = (ResidualMode.STANDARD, ResidualMode.LADDER, ResidualMode.DESYNC2)

# phase name -> (batch, seq_new, kv_len)
PHASES = dict(decode=(8, 1, 1280), prefill=(1, 1024, 1024))


def model_rows(args):
    """The analytical sweep: (hw, tp, phase) x residual mode x wire format."""
    cfg = get_config(args.arch, residual="ladder")
    rows = []
    for hw_key in args.hws.split(","):
        hw = HWS[hw_key]
        for tp in (2, 8):
            for phase, (batch, seq_new, kv_len) in PHASES.items():
                t = batch * seq_new
                wire_fp = ar_wire_bytes(t, cfg.d_model, tp)
                wire_q = ar_wire_bytes(t, cfg.d_model, tp, quant=True)
                for comm, chunks, quant in (
                        ("sync", 1, False),
                        ("overlap", args.chunks, False),
                        ("compressed", args.chunks, True)):
                    lc = layer_cost(cfg, tp=tp, batch=batch, seq_new=seq_new,
                                    kv_len=kv_len, hw=hw, comm_chunks=chunks,
                                    comm_quant=quant)
                    std = exposed_comm(ResidualMode.STANDARD, lc)
                    for mode in MODES:
                        rep = exposed_comm(mode, lc)
                        rows.append(dict(
                            scenario="model", hw=hw_key, tp=tp, phase=phase,
                            mode=mode.value, comm=comm, chunks=chunks,
                            wire_bytes=round(wire_q if quant else wire_fp),
                            t_comm_us=round(lc.t_comm * 1e6, 3),
                            t_attn_us=round(lc.t_attn * 1e6, 3),
                            t_mlp_us=round(lc.t_mlp * 1e6, 3),
                            t_exposed_us=round(rep["t_exposed"] * 1e6, 3),
                            t_hidden_us=round(rep["t_hidden"] * 1e6, 3),
                            hidden_frac=round(rep["hidden_frac"], 4),
                            hidden_vs_standard=round(
                                rep["t_hidden"] / std["t_exposed"], 4)
                            if std["t_exposed"] > 0 else 0.0,
                            wire_reduction=round(wire_fp / wire_q, 3)
                            if quant and wire_q else 1.0,
                            gated=hw_key == "nvlink" and
                            (comm == "sync" or phase == "prefill"),
                        ))
    return rows


def _time_fn(fn, *args, iters):
    jax.block_until_ready(fn(*args))  # compile outside the clock
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measured_rows(args):
    """Wall time of the real collectives at TP=2 on this host (fake
    devices on CPU — correctness/overhead column, not link bandwidth)."""
    if len(jax.devices()) < 2:
        return []
    cfg = get_config(args.arch, residual="ladder")
    mesh = compat.make_mesh((2,), ("model",))
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(2, 8, cfg.d_model)),
        jnp.float32)

    def run(fn):
        wrapped = compat.shard_map(fn, mesh, P("model"), P("model"))
        with compat.set_mesh(mesh):
            return _time_fn(jax.jit(wrapped), x, iters=args.iters)

    variants = dict(
        psum=lambda v: jax.lax.psum(v, "model"),
        ring=lambda v: overlap.ring_all_reduce(
            v, "model", chunks=args.chunks),
        compressed=lambda v: overlap.compressed_ring_all_reduce(
            v, "model", chunks=args.chunks),
    )
    return [dict(scenario="measured", comm=name, tp=2,
                 shape=list(x.shape[1:]),
                 t_us=round(run(fn) * 1e6, 1),
                 backend=jax.default_backend())
            for name, fn in variants.items()]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ladder-3b")
    ap.add_argument("--hws", default="nvlink,no_nvlink",
                    help="comma-separated core.schedule.HWS keys")
    ap.add_argument("--chunks", type=int, default=4,
                    help="ring chunk count for overlap/compressed rows")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "results" / "comm_bench.json"))
    add_update_baseline_arg(ap)
    args = ap.parse_args(argv)

    rows = model_rows(args) + measured_rows(args)
    cfg = {k: v for k, v in vars(args).items() if k != "update_baseline"}
    record = dict(bench="comm_bench", config=cfg, rows=rows)
    write_record(record, args.out, args.update_baseline)

    print("name,us_per_call,derived")
    for r in rows:
        if r["scenario"] == "measured":
            print(f"comm_bench/measured-{r['comm']},{r['t_us']:.1f},"
                  f"tp={r['tp']} backend={r['backend']}")
        elif r["mode"] == "ladder":  # the gated rows; others live in JSON
            print(f"comm_bench/{r['hw']}-tp{r['tp']}-{r['phase']}-"
                  f"{r['comm']},{r['t_exposed_us']:.3f},"
                  f"t_comm={r['t_comm_us']}us "
                  f"wire={r['wire_bytes']}B "
                  f"hidden_frac={r['hidden_frac']} "
                  f"hidden_vs_standard={r['hidden_vs_standard']} "
                  f"gated={r['gated']}")
    return record


if __name__ == "__main__":
    main()
