"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Wall-clock numbers come from
the analytical timeline model (core/schedule.py) calibrated to the paper's
H100 setups — this container has no TPU/GPU, so modeled latencies are the
benchmark (EXPERIMENTS.md cross-checks them against the paper's measured
speedups).  The roofline table reads the compiled dry-run artifacts
(results/dryrun.json) produced by repro.launch.dryrun.
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import REGISTRY  # noqa: E402
from repro.core import schedule as sched                       # noqa: E402

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun.json"
KERNEL_BENCH = Path(__file__).resolve().parents[1] / "results" / \
    "kernel_bench.json"
COMM_BENCH = Path(__file__).resolve().parents[1] / "results" / \
    "comm_bench.json"

PAPER_TABLE1 = {  # model -> (no_nvlink, with_nvlink) measured speedups
    "ladder-1b": (1.39, 1.56), "ladder-3b": (1.50, 1.57),
    "llama3-8b": (1.40, 1.46), "llama-34b": (1.47, 1.44),
    "llama3-70b": (1.59, 1.29), "bloom-176b": (1.54, 1.35),
    "llama3-405b": (1.57, 1.31),
}


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def table1_inference_speedup():
    """Paper Table 1: ladder vs standard, 1024+512 generation, batch 4,
    TP8 (TP16 for 405B), with/without fast interconnect."""
    for arch, (paper_no, paper_with) in PAPER_TABLE1.items():
        cfg = REGISTRY[arch]
        tp = 16 if arch == "llama3-405b" else 8
        for hw_name, paper in [("no_nvlink", paper_no),
                               ("nvlink", paper_with)]:
            hw = sched.HWS[hw_name if arch != "llama3-405b" else
                           ("cross_node" if hw_name == "no_nvlink"
                            else "nvlink")]
            rows = sched.speedup_table(cfg, tp=tp, batch=4, prompt=1024,
                                       gen=512, hw=hw)
            us = 1e6 / rows["standard"]["tok_per_s"]
            got = rows["ladder"]["speedup"]
            _emit(f"table1/{arch}/{hw_name}", us,
                  f"ladder_speedup={got:.2f}x paper={paper:.2f}x")


def table2_latency_breakdown():
    """Paper Table 2: 70B, batch 1, TP8 — prefill/decode/token-rate
    improvements for parallel, ladder, upper bound."""
    cfg = REGISTRY["llama3-70b"]
    for hw_name in ["nvlink", "no_nvlink"]:
        rows = sched.speedup_table(cfg, tp=8, batch=1, prompt=1024, gen=512,
                                   hw=sched.HWS[hw_name])
        for mode in ["parallel", "ladder", "no_comm"]:
            r = rows[mode]
            us = 1e6 / rows["standard"]["tok_per_s"]
            _emit(f"table2/70b/{hw_name}/{mode}", us,
                  f"prefill+{100*r['prefill_improvement']:.1f}% "
                  f"decode+{100*r['decode_improvement']:.1f}% "
                  f"tok/s x{r['speedup']:.2f}")


def figure2_throughput_sweep():
    """Paper Figure 2: 70B throughput improvement across TP x batch."""
    cfg = REGISTRY["llama3-70b"]
    for hw_name in ["nvlink", "no_nvlink"]:
        for tp in [2, 4, 8]:
            for batch in [1, 4, 16, 64]:
                rows = sched.speedup_table(cfg, tp=tp, batch=batch,
                                           prompt=1024, gen=512,
                                           hw=sched.HWS[hw_name])
                us = 1e6 / rows["standard"]["tok_per_s"]
                _emit(f"figure2/{hw_name}/tp{tp}/b{batch}", us,
                      f"ladder x{rows['ladder']['speedup']:.2f}")


def figure3_cross_node_405b():
    """Paper Figure 3: 405B across two nodes (TP16 over IB)."""
    cfg = REGISTRY["llama3-405b"]
    for batch in [1, 4, 16]:
        rows = sched.speedup_table(cfg, tp=16, batch=batch, prompt=1024,
                                   gen=512, hw=sched.HWS["cross_node"])
        us = 1e6 / rows["standard"]["tok_per_s"]
        _emit(f"figure3/405b/b{batch}", us,
              f"ladder x{rows['ladder']['speedup']:.2f} "
              f"upper x{rows['no_comm']['speedup']:.2f}")


def table6_desync():
    """Paper Table 6: 8B, batch 64, TP8 — desync vs ladder."""
    cfg = REGISTRY["llama3-8b"]
    for hw_name in ["nvlink", "no_nvlink"]:
        rows = sched.speedup_table(cfg, tp=8, batch=64, prompt=1024,
                                   gen=512, hw=sched.HWS[hw_name])
        for mode in ["ladder", "desync2", "desync4", "no_comm"]:
            r = rows[mode]
            us = 1e6 / rows["standard"]["tok_per_s"]
            _emit(f"table6/8b/{hw_name}/{mode}", us,
                  f"tok/s x{r['speedup']:.2f} "
                  f"decode+{100*r['decode_improvement']:.1f}%")


def tpu_projection():
    """Beyond-paper: the same protocol on the dry-run's TPU v5e mesh."""
    for arch in ["llama3-70b", "dbrx-132b", "deepseek-v2-lite-16b"]:
        cfg = REGISTRY[arch]
        rows = sched.speedup_table(cfg, tp=16, batch=8, prompt=1024,
                                   gen=512, hw=sched.TPU_V5E)
        us = 1e6 / rows["standard"]["tok_per_s"]
        _emit(f"tpu_v5e/{arch}", us,
              f"ladder x{rows['ladder']['speedup']:.2f} "
              f"desync4 x{rows['desync4']['speedup']:.2f}")


def roofline_table():
    """Per (arch x shape) roofline terms from the compiled dry-run."""
    if not RESULTS.exists():
        print("roofline,0,missing results/dryrun.json (run repro.launch.dryrun)")
        return
    rows = json.loads(RESULTS.read_text())
    for r in sorted(rows, key=lambda r: r.get("cell", "")):
        if r.get("status") != "ok":
            continue
        us = max(r["t_compute"], r.get("t_memory_nocopy", r["t_memory"]),
                 r["t_collective"]) * 1e6
        _emit(f"roofline/{r['cell']}", us,
              f"bottleneck={r['bottleneck']} "
              f"t_comp={r['t_compute']*1e3:.1f}ms "
              f"t_mem={r.get('t_memory_nocopy', r['t_memory'])*1e3:.1f}ms "
              f"t_coll={r['t_collective']*1e3:.1f}ms "
              f"useful={r['useful_ratio']:.2f}")


def kernel_bench_table():
    """Paged-attention kernel vs gather read: bytes-read model + step time
    per pool occupancy, from the committed benchmarks/kernel_bench.py
    artifact (kernel traffic must scale with actual kv length —
    scripts/check_bench.py gates the same rows)."""
    if not KERNEL_BENCH.exists():
        print("kernel_bench,0,missing results/kernel_bench.json "
              "(run benchmarks/kernel_bench.py)")
        return
    rows = json.loads(KERNEL_BENCH.read_text())["rows"]
    for r in rows:
        tag = r["scenario"] if r["scenario"] != "uniform" else \
            f"occ{r['occupancy']}"
        _emit(f"kernel_bench/{tag}", r["t_kernel_us"],
              f"kv_bytes kernel={r['bytes_kernel']} "
              f"gather_full={r['bytes_gather_full']} "
              f"gather_sliced={r['bytes_gather_sliced']} "
              f"x{r['reduction_vs_full']} vs full "
              f"(t_gather={r['t_gather_us']}us"
              f"{', interpret' if r['kernel_interpreted'] else ''})")


def comm_bench_table():
    """Exposed-vs-hidden TP comm per residual mode + wire format, from the
    committed benchmarks/comm_bench.py artifact (ladder must hide >= 30%
    of standard's exposed comm on the gated rows — scripts/check_bench.py
    gates the same rows)."""
    if not COMM_BENCH.exists():
        print("comm_bench,0,missing results/comm_bench.json "
              "(run benchmarks/comm_bench.py)")
        return
    rows = json.loads(COMM_BENCH.read_text())["rows"]
    for r in rows:
        if r["scenario"] == "measured":
            _emit(f"comm_bench/measured-{r['comm']}", r["t_us"],
                  f"tp={r['tp']} backend={r['backend']}")
        elif r["mode"] == "ladder":
            _emit(f"comm_bench/{r['hw']}-tp{r['tp']}-{r['phase']}-"
                  f"{r['comm']}", r["t_exposed_us"],
                  f"t_comm={r['t_comm_us']}us wire={r['wire_bytes']}B "
                  f"hidden_frac={r['hidden_frac']} "
                  f"hidden_vs_standard={r['hidden_vs_standard']} "
                  f"gated={r['gated']}")


TABLES = {
    "table1": table1_inference_speedup,
    "table2": table2_latency_breakdown,
    "figure2": figure2_throughput_sweep,
    "figure3": figure3_cross_node_405b,
    "table6": table6_desync,
    "tpu": tpu_projection,
    "roofline": roofline_table,
    "kernel_bench": kernel_bench_table,
    "comm_bench": comm_bench_table,
}


def main(argv=None) -> None:
    """Run the named tables (all of them with no arguments):

        python benchmarks/run.py [table1 table2 figure2 figure3 table6
                                  tpu roofline ...]
    """
    names = argv if argv is not None else sys.argv[1:]
    unknown = [n for n in names if n not in TABLES]
    if unknown:
        raise SystemExit(f"unknown table(s) {unknown}; "
                         f"choose from {sorted(TABLES)}")
    print("name,us_per_call,derived")
    for name, fn in TABLES.items():
        if not names or name in names:
            fn()


if __name__ == "__main__":
    main()
