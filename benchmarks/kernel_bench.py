"""Paged decode-attention kernel benchmark: step time + bytes-read model.

The point of the block-table-native kernel (kernels/paged_attention.py) is
that its HBM traffic scales with each row's ACTUAL kv length, while the
gather path (``paged_view``) materialises the full table width per row
before attending.  This bench pins that down two ways, across pool
occupancies:

* **bytes-read model** — analytical KV bytes touched per decode step:

    gather (full table) : B * max_blocks        * bs * 2 * Hkv * hd * isize
    gather (live-sliced): B * bucket(used_blks) * bs * 2 * Hkv * hd * isize
    kernel              : sum_b ceil(kv_len_b / bs) * bs * 2 * Hkv * hd * isize
    kernel (int8 pool)  : sum_b ceil(kv_len_b / bs) * bs * 2 * Hkv * (hd + 4)

  "gather (live-sliced)" is the oracle path after the host-side table
  slicing fix (scheduler.PagedServingEngine._bt_width): its traffic tracks
  occupancy in power-of-two buckets, but every row still pays the batch
  max; the kernel's per-row early exit pays only its own length.  q, block
  table, and output bytes are identical across paths and omitted.  The
  int8 row is the kernel walking an int8 pool (DESIGN.md §KV memory
  tiers): each (token, head) reads hd int8 elements plus one f32 scale per
  k and v, dequantized in VMEM — a further ~4x (f32 pools) / ~2x (bf16)
  cut on top of the occupancy win, gated at >= 1.8x by check_bench.py.

* **measured step time** — wall time of the jitted decode-attention read
  on THIS host.  On CPU the kernel runs in Pallas interpret mode (the
  kernel body executes op-by-op in Python), so the gather path wins wall
  clock here; the timing column exists to catch pathological regressions
  and becomes meaningful on a real TPU.  The bytes model is the
  hardware-relevant result and is what scripts/check_bench.py gates
  (kernel < full-table gather at >= 50% occupancy; >= 4x reduction at
  25%).

    PYTHONPATH=src python benchmarks/kernel_bench.py \
        --out results/kernel_bench.json
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from bench_io import add_update_baseline_arg, write_record  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.kernels import autotune, ops  # noqa: E402
from repro.kernels.paged_attention import prefill_kernel_blocks  # noqa: E402
from repro.models.attention import _cached_attention  # noqa: E402
from repro.parallel.collectives import NULL_ENV  # noqa: E402
from repro.serving.kv_cache import PagedKVCache, paged_view  # noqa: E402
from repro.serving.scheduler import _bucket  # noqa: E402


from repro.serving.kv_cache import kv_block_bytes  # noqa: E402


def _kv_bytes(n_blocks_read, bs, hkv, hd, isize):
    return n_blocks_read * kv_block_bytes(bs, hkv, hd, isize)


def _kv_bytes_int8(n_blocks_read, bs, hkv, hd):
    # int8 element + one f32 scale per (token, head) per k/v plane
    return n_blocks_read * kv_block_bytes(bs, hkv, hd, 0, "int8")


def _time_fn(fn, *args, iters):
    jax.block_until_ready(fn(*args))  # compile outside the clock
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _bench_case(scenario, kv_lens, args):
    """One row: per-row kv lengths `kv_lens`, decode (Q=1)."""
    bs, hkv, hd = args.block_size, args.kv_heads, args.head_dim
    b = len(kv_lens)
    max_blocks = args.max_blocks
    used = [-(-kv // bs) for kv in kv_lens]
    hq = hkv * args.group
    dtype = jnp.float32
    isize = jnp.dtype(dtype).itemsize

    key = jax.random.key(0)
    q = jax.random.normal(key, (b, 1, hq, hd), dtype)
    num_blocks = b * max_blocks
    k = jax.random.normal(
        jax.random.fold_in(key, 1), (hkv, num_blocks * bs, hd), dtype
    )
    v = jax.random.normal(
        jax.random.fold_in(key, 2), (hkv, num_blocks * bs, hd), dtype
    )
    rng = np.random.default_rng(0)
    bt_full = jnp.asarray(
        rng.permutation(num_blocks).reshape(b, max_blocks), jnp.int32
    )
    qpos = jnp.asarray([[kv - 1] for kv in kv_lens], jnp.int32)
    scale = hd**-0.5
    # the engine's host-side slice: power-of-two bucket of the batch max
    w = min(_bucket(max(used), 1), max_blocks)
    bt_live = bt_full[:, :w]

    def gather_read(q, k, v, bt, qpos):
        cache = PagedKVCache(k=k, v=v, block_size=bs)
        view = paged_view(cache, bt)
        return _cached_attention(q * scale, view, qpos, NULL_ENV, softcap=0.0)

    def kernel_read(q, k, v, bt, qpos):
        return ops.paged_attention(q, k, v, bt, qpos, scale=scale, block_size=bs)

    # tuned launch geometry: what the serving engine dispatches for this
    # (phase, occupancy-bucket) via the committed tuning table — same
    # static key the engine derives (table width / max table width)
    occ = w / max_blocks
    tuned_cfg = autotune.get_config("decode", occ, block_size=bs)

    def kernel_read_tuned(q, k, v, bt, qpos):
        return ops.paged_attention(
            q, k, v, bt, qpos, scale=scale, block_size=bs, phase="decode", occ=occ
        )

    # int8 pool: same contents quantized per (token, head); the kernel
    # streams int8 tiles + scale tiles and dequantizes in VMEM
    from repro.quant import quantize_kv

    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)

    def kernel_read_int8(q, k8, v8, ks, vs, bt, qpos):
        return ops.paged_attention(
            q, k8, v8, bt, qpos, scale=scale, block_size=bs, k_scale=ks, v_scale=vs
        )

    gather = jax.jit(gather_read)
    t_gather = _time_fn(gather, q, k, v, bt_live, qpos, iters=args.iters)
    t_kernel = _time_fn(kernel_read, q, k, v, bt_live, qpos, iters=args.iters)
    # a cell whose tuned geometry IS the default dispatches the identical
    # compiled call — re-timing it would only race the clock
    if (tuned_cfg.num_splits, tuned_cfg.q_tile) == (0, 0):
        t_kernel_tuned = t_kernel
    else:
        t_kernel_tuned = _time_fn(
            kernel_read_tuned, q, k, v, bt_live, qpos, iters=args.iters
        )
    t_kernel_int8 = _time_fn(
        kernel_read_int8, q, k8, v8, ks, vs, bt_live, qpos, iters=args.iters
    )

    bytes_full = _kv_bytes(b * max_blocks, bs, hkv, hd, isize)
    bytes_sliced = _kv_bytes(b * w, bs, hkv, hd, isize)
    bytes_kernel = _kv_bytes(sum(used), bs, hkv, hd, isize)
    bytes_kernel_int8 = _kv_bytes_int8(sum(used), bs, hkv, hd)
    return dict(
        scenario=scenario,
        occupancy=round(sum(used) / (b * max_blocks), 4),
        kv_lens=list(kv_lens),
        rows=b,
        max_blocks=max_blocks,
        blocks_used=used,
        bt_width=w,
        bytes_gather_full=bytes_full,
        bytes_gather_sliced=bytes_sliced,
        bytes_kernel=bytes_kernel,
        bytes_kernel_int8=bytes_kernel_int8,
        reduction_vs_full=round(bytes_full / bytes_kernel, 3),
        reduction_vs_sliced=round(bytes_sliced / bytes_kernel, 3),
        reduction_int8_vs_fp=round(bytes_kernel / bytes_kernel_int8, 3),
        t_gather_us=round(t_gather * 1e6, 1),
        t_kernel_us=round(t_kernel * 1e6, 1),
        t_kernel_tuned_us=round(t_kernel_tuned * 1e6, 1),
        tuned_num_splits=tuned_cfg.num_splits,
        tuned_q_tile=tuned_cfg.q_tile,
        t_kernel_int8_us=round(t_kernel_int8 * 1e6, 1),
        kernel_interpreted=jax.default_backend() != "tpu",
    )


def bench_occupancy(occ, args):
    """Uniform rows at kv_len = occ * s_max — the occupancy sweep the
    regression gate reads (scripts/check_bench.py)."""
    s_max = args.max_blocks * args.block_size
    kv = max(1, int(round(occ * s_max)))
    return _bench_case("uniform", [kv] * args.rows, args)


def bench_ragged(args):
    """One long row pinning the batch max + short tails: the sliced gather
    still pays bucket(batch max) for EVERY row, the kernel's per-row early
    exit pays each row's own length — the regime continuous batching
    actually serves."""
    s_max = args.max_blocks * args.block_size
    kv_lens = [s_max] + [max(1, s_max // 8)] * (args.rows - 1)
    return _bench_case("ragged", kv_lens, args)


def _bench_prefill(kv_lens, chunk, args):
    """One prefill/append row: each request appends a `chunk`-query tail
    ending at its kv_len (history already paged in), the regime chunked
    prefill and prefix-cache-hit appends actually run.  The kernel's
    bytes model is O(sum_b tiles * ceil(tile_hi / bs)) via
    prefill_kernel_blocks — per-row causal extent, never the table width
    — while the gather path materialises O(W) per row before attending."""
    bs, hkv, hd = args.block_size, args.kv_heads, args.head_dim
    b = len(kv_lens)
    max_blocks = args.max_blocks
    used = [-(-kv // bs) for kv in kv_lens]
    hq = hkv * args.group
    dtype = jnp.float32
    isize = jnp.dtype(dtype).itemsize

    key = jax.random.key(1)
    q = jax.random.normal(key, (b, chunk, hq, hd), dtype)
    num_blocks = b * max_blocks
    k = jax.random.normal(
        jax.random.fold_in(key, 1), (hkv, num_blocks * bs, hd), dtype
    )
    v = jax.random.normal(
        jax.random.fold_in(key, 2), (hkv, num_blocks * bs, hd), dtype
    )
    rng = np.random.default_rng(1)
    bt_full = jnp.asarray(
        rng.permutation(num_blocks).reshape(b, max_blocks), jnp.int32
    )
    qpos = jnp.asarray(
        [[kv - chunk + i for i in range(chunk)] for kv in kv_lens], jnp.int32
    )
    scale = hd**-0.5
    w = min(_bucket(max(used), 1), max_blocks)
    bt_live = bt_full[:, :w]
    occ = w / max_blocks
    tuned_cfg = autotune.get_config("prefill", occ, block_size=bs)

    def gather_read(q, k, v, bt, qpos):
        cache = PagedKVCache(k=k, v=v, block_size=bs)
        view = paged_view(cache, bt)
        return _cached_attention(q * scale, view, qpos, NULL_ENV, softcap=0.0)

    def kernel_read(q, k, v, bt, qpos):
        return ops.paged_attention(q, k, v, bt, qpos, scale=scale, block_size=bs)

    def kernel_read_tuned(q, k, v, bt, qpos):
        return ops.paged_attention(
            q, k, v, bt, qpos, scale=scale, block_size=bs, phase="prefill", occ=occ
        )

    gather = jax.jit(gather_read)
    t_gather = _time_fn(gather, q, k, v, bt_live, qpos, iters=args.iters)
    t_kernel = _time_fn(kernel_read, q, k, v, bt_live, qpos, iters=args.iters)
    # identical compiled call when the tuned geometry is the default (see
    # _bench_case)
    if (tuned_cfg.num_splits, tuned_cfg.q_tile) == (0, 0):
        t_kernel_tuned = t_kernel
    else:
        t_kernel_tuned = _time_fn(
            kernel_read_tuned, q, k, v, bt_live, qpos, iters=args.iters
        )

    blocks_kernel = sum(prefill_kernel_blocks(kv, chunk, 0, bs) for kv in kv_lens)
    blocks_tuned = sum(
        prefill_kernel_blocks(kv, chunk, tuned_cfg.q_tile, bs) for kv in kv_lens
    )
    bytes_full = _kv_bytes(b * max_blocks, bs, hkv, hd, isize)
    bytes_sliced = _kv_bytes(b * w, bs, hkv, hd, isize)
    bytes_kernel = _kv_bytes(blocks_kernel, bs, hkv, hd, isize)
    bytes_tuned = _kv_bytes(blocks_tuned, bs, hkv, hd, isize)
    return dict(
        scenario="prefill",
        chunk=chunk,
        occupancy=round(sum(used) / (b * max_blocks), 4),
        kv_lens=list(kv_lens),
        rows=b,
        max_blocks=max_blocks,
        blocks_used=used,
        bt_width=w,
        bytes_gather_full=bytes_full,
        bytes_gather_sliced=bytes_sliced,
        bytes_kernel=bytes_kernel,
        bytes_kernel_tuned=bytes_tuned,
        reduction_vs_full=round(bytes_full / bytes_kernel, 3),
        reduction_vs_sliced=round(bytes_sliced / bytes_kernel, 3),
        t_gather_us=round(t_gather * 1e6, 1),
        t_kernel_us=round(t_kernel * 1e6, 1),
        t_kernel_tuned_us=round(t_kernel_tuned * 1e6, 1),
        tuned_num_splits=tuned_cfg.num_splits,
        tuned_q_tile=tuned_cfg.q_tile,
        kernel_interpreted=jax.default_backend() != "tpu",
    )


def bench_prefill(args):
    """Ragged chunked-prefill rows: one full-history row pinning the
    batch-max table width plus progressively shorter histories, all
    appending the same chunk (kernels/autotune.py's prefill phase shape)."""
    s_max = args.max_blocks * args.block_size
    chunk = min(16, s_max)
    kv_lens = [s_max, max(chunk, s_max // 2), max(chunk, s_max // 4)]
    kv_lens += [chunk] * (args.rows - len(kv_lens))
    return _bench_prefill(kv_lens[: args.rows], chunk, args)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4, help="batch rows (slots)")
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument(
        "--group", type=int, default=2, help="GQA group (Hq = kv_heads * group)"
    )
    ap.add_argument("--head-dim", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument(
        "--max-blocks",
        type=int,
        default=16,
        help="table width per row (s_max = max_blocks * bs)",
    )
    ap.add_argument("--occupancies", default="0.125,0.25,0.5,0.75,1.0")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument(
        "--out",
        default=str(
            Path(__file__).resolve().parents[1] / "results" / "kernel_bench.json"
        ),
    )
    add_update_baseline_arg(ap)
    args = ap.parse_args(argv)

    rows = [bench_occupancy(float(o), args) for o in args.occupancies.split(",")]
    rows.append(bench_ragged(args))
    rows.append(bench_prefill(args))
    cfg = {k: v for k, v in vars(args).items() if k != "update_baseline"}
    record = dict(bench="kernel_bench", config=cfg, rows=rows)
    write_record(record, args.out, args.update_baseline)

    print("name,us_per_call,derived")
    for r in rows:
        tag = f"occ{r['occupancy']}" if r["scenario"] == "uniform" else r["scenario"]
        interp = " (interpret)" if r["kernel_interpreted"] else ""
        int8 = (
            f"bytes_kernel_int8={r['bytes_kernel_int8']} "
            f"reduction_int8_vs_fp={r['reduction_int8_vs_fp']}x "
            if "bytes_kernel_int8" in r
            else f"chunk={r['chunk']} bytes_kernel_tuned={r['bytes_kernel_tuned']} "
        )
        print(
            f"kernel_bench/{tag},{r['t_kernel_us']:.1f},"
            f"bytes_kernel={r['bytes_kernel']} "
            f"{int8}"
            f"bytes_gather_full={r['bytes_gather_full']} "
            f"bytes_gather_sliced={r['bytes_gather_sliced']} "
            f"reduction_vs_full={r['reduction_vs_full']}x "
            f"reduction_vs_sliced={r['reduction_vs_sliced']}x "
            f"t_tuned={r['t_kernel_tuned_us']:.1f}us"
            f"[s{r['tuned_num_splits']}q{r['tuned_q_tile']}] "
            f"t_gather={r['t_gather_us']:.1f}us{interp}"
        )
    return record


if __name__ == "__main__":
    main()
